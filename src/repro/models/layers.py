"""Shared neural building blocks (pure JAX, functional params-as-pytrees).

Conventions:
* ``init_*`` functions return plain dicts of arrays (param_dtype);
* ``apply`` functions cast to the compute dtype at use sites and keep
  normalisation/softmax statistics in float32;
* every function takes an optional :class:`~repro.models.sharding.Sharder`
  and constrains the activations it produces — GSPMD propagates the rest.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _cast(x, dtype):
    return x.astype(dtype) if x.dtype != jnp.dtype(dtype) else x


# --------------------------------------------------------------------------
# initialisers
# --------------------------------------------------------------------------


def dense_init(key, in_dim, out_dim, dtype, *, bias=False, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    p = {"w": jax.random.normal(key, (in_dim, out_dim), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(p, x, dtype):
    y = x @ _cast(p["w"], dtype)
    if "b" in p:
        y = y + _cast(p["b"], dtype)
    return y


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rmsnorm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------


def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta))  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# GQA attention (reference XLA path; the Pallas kernels mirror this math)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class AttnParamsSpec:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False


def attention_init(key, spec: AttnParamsSpec, dtype):
    ks = jax.random.split(key, 4)
    d, h, hk, hd = spec.d_model, spec.num_heads, spec.num_kv_heads, spec.head_dim
    s = 1.0 / np.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, h, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hk, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hk, hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (h, hd, d), dtype) * (1.0 / np.sqrt(h * hd)),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((hk, hd), dtype)
        p["bv"] = jnp.zeros((hk, hd), dtype)
    return p


def _project_qkv(p, x, dtype, x_kv=None):
    xkv = x if x_kv is None else x_kv
    q = jnp.einsum("btd,dhk->bthk", x, _cast(p["wq"], dtype))
    k = jnp.einsum("bsd,dhk->bshk", xkv, _cast(p["wk"], dtype))
    v = jnp.einsum("bsd,dhk->bshk", xkv, _cast(p["wv"], dtype))
    if "bq" in p:
        q = q + _cast(p["bq"], dtype)
        k = k + _cast(p["bk"], dtype)
        v = v + _cast(p["bv"], dtype)
    return q, k, v


def gqa_scores_softmax_value(q, k, v, mask, *, q_per_kv):
    """Grouped attention without materialising repeated KV.

    q: (b, t, h, hd) with h = hk * q_per_kv; k, v: (b, s, hk, hd);
    mask: broadcastable to (b, 1, 1, t, s) boolean (True = attend).
    """
    b, t, h, hd = q.shape
    hk = k.shape[2]
    qg = q.reshape(b, t, hk, q_per_kv, hd)
    scores = jnp.einsum("bthgk,bshk->bhgts", qg, k) / np.sqrt(hd)
    scores = scores.astype(jnp.float32)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgts,bshk->bthgk", probs, v)
    return out.reshape(b, t, h, hd)


def _quantize_kv(x):
    """Per-(b, t, head) symmetric int8: x (B, t, hk, hd) ->
    (int8 same shape, f32 scale (B, t, hk, 1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def chunked_causal_attention(q, k, v, *, q_per_kv, causal=True, window=None,
                             chunk=1024, causal_skip=False):
    """Q-chunked attention: bounds the score tile to (chunk, S) so 32k+
    prefills never materialise the full (S, S) matrix (the XLA-path
    equivalent of the flash kernel's tiling).

    ``causal_skip`` (§Perf lever): each chunk attends only to keys up to its
    own end — the kv extent grows per chunk (statically sliced, so the loop
    is unrolled).  Halves both attention flops and score-tile traffic versus
    the scan-over-full-S baseline.
    """
    b, t, h, hd = q.shape
    s = k.shape[1]
    nq = t // chunk
    assert t % chunk == 0, "attn_chunk must divide sequence length"

    if causal and causal_skip and s == t:
        outs = []
        for i in range(nq):
            qc = q[:, i * chunk:(i + 1) * chunk]
            kv_end = (i + 1) * chunk
            kv_start = 0 if window is None else max(0, kv_end - window - chunk)
            mask = causal_mask(chunk, kv_end - kv_start,
                               q_offset=i * chunk - kv_start, window=window)
            outs.append(gqa_scores_softmax_value(
                qc, k[:, kv_start:kv_end], v[:, kv_start:kv_end], mask,
                q_per_kv=q_per_kv,
            ))
        return jnp.concatenate(outs, axis=1)

    qs = q.reshape(b, nq, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    def body(idx, qc):
        offset = idx * chunk
        if causal:
            mask = causal_mask(chunk, s, q_offset=offset, window=window)
        else:
            mask = jnp.ones((1, 1, 1, chunk, s), bool)
        out = gqa_scores_softmax_value(qc, k, v, mask, q_per_kv=q_per_kv)
        return idx + 1, out

    _, outs = jax.lax.scan(body, jnp.int32(0), qs)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, t, h, hd)


def causal_mask(t, s, q_offset=0, window=None):
    """(1,1,1,t,s) boolean; query position i = q_offset + i attends to
    key positions j <= i (and j > i - window when windowed)."""
    qi = jnp.arange(t)[:, None] + q_offset
    kj = jnp.arange(s)[None, :]
    m = kj <= qi
    if window is not None:
        m = m & (kj > qi - window)
    return m[None, None, None]


def attention_apply(
    p,
    x,
    *,
    spec: AttnParamsSpec,
    dtype,
    rope_theta: float | None,
    positions,
    causal: bool = True,
    window: int | None = None,
    cache: dict | None = None,
    cache_pos=None,
    x_kv=None,
    sharder=None,
    static_cache: bool = False,
    attn_chunk: int | None = None,
    causal_skip: bool = False,
):
    """Full/causal/cross attention with optional KV cache.

    Modes:
    * train/prefill:   cache=None -> attend within x (returns new cache built
                       from k, v when ``return_cache`` via prefill wrapper)
    * decode:          cache={'k','v'} (b, S, hk, hd); the t new tokens are
                       written at ``cache_pos`` and attend over the cache.
    """
    q, k, v = _project_qkv(p, x, dtype, x_kv=x_kv)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        if x_kv is None:  # self-attention: keys share the query positions
            k = apply_rope(k, positions, rope_theta)
    if sharder is not None:
        q = sharder.constrain(q, ["batch", None, "model", None])
        k = sharder.constrain(k, ["batch", None, "model", None])
        v = sharder.constrain(v, ["batch", None, "model", None])

    new_cache = None
    if cache is not None and static_cache:
        # read-only cache (e.g. cross-attention over precomputed encoder
        # K/V during decode): attend over every slot, no update
        S = cache["k"].shape[1]
        mask = jnp.ones((1, 1, 1, q.shape[1], S), bool)
        out = gqa_scores_softmax_value(
            q, cache["k"], cache["v"], mask,
            q_per_kv=spec.num_heads // spec.num_kv_heads,
        )
        new_cache = cache
    elif cache is not None and "k_scale" in cache:
        # int8-quantised KV cache (kv_quant §Perf lever): values stored as
        # int8 with one f32 scale per (batch, pos, head) vector — 2x less
        # cache HBM traffic than bf16 at <0.5% attention-output error
        S = cache["k"].shape[1]
        kq, ks_ = _quantize_kv(k)
        vq, vs_ = _quantize_kv(v)
        per_slot = hasattr(cache_pos, "ndim") and cache_pos.ndim == 1
        if per_slot:
            bidx = jnp.arange(cache["k"].shape[0])
            new_cache = {
                "k": cache["k"].at[bidx, cache_pos].set(kq[:, 0]),
                "v": cache["v"].at[bidx, cache_pos].set(vq[:, 0]),
                "k_scale": cache["k_scale"].at[bidx, cache_pos].set(ks_[:, 0]),
                "v_scale": cache["v_scale"].at[bidx, cache_pos].set(vs_[:, 0]),
            }
        else:
            dus = jax.lax.dynamic_update_slice
            new_cache = {
                "k": dus(cache["k"], kq, (0, cache_pos, 0, 0)),
                "v": dus(cache["v"], vq, (0, cache_pos, 0, 0)),
                "k_scale": dus(cache["k_scale"], ks_, (0, cache_pos, 0, 0)),
                "v_scale": dus(cache["v_scale"], vs_, (0, cache_pos, 0, 0)),
            }
        ck = new_cache["k"].astype(q.dtype) * new_cache["k_scale"].astype(q.dtype)
        cv = new_cache["v"].astype(q.dtype) * new_cache["v_scale"].astype(q.dtype)
        kj = jnp.arange(S)[None, :]
        qi = positions[..., :, None]
        valid = kj[None] <= qi if qi.ndim == 3 else kj <= qi
        mask = valid[:, None, None] if valid.ndim == 3 else valid[None, None, None]
        out = gqa_scores_softmax_value(
            q, ck, cv, mask, q_per_kv=spec.num_heads // spec.num_kv_heads
        )
    elif cache is not None:
        # positions: (t,) for synchronous batch decode, or (B, t) for
        # per-slot decode (continuous batching in the serving engine);
        # cache slots are linear, or a ring buffer of size S=window for
        # windowed attention (long-context hybrid cells)
        S = cache["k"].shape[1]
        per_slot = hasattr(cache_pos, "ndim") and cache_pos.ndim == 1
        if per_slot:
            B = cache["k"].shape[0]
            widx = (cache_pos % S) if window is not None else cache_pos
            bidx = jnp.arange(B)
            ck = cache["k"].at[bidx, widx].set(k[:, 0])
            cv = cache["v"].at[bidx, widx].set(v[:, 0])
        else:
            write_idx = (cache_pos % S) if window is not None else cache_pos
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, write_idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, write_idx, 0, 0))
        new_cache = {"k": ck, "v": cv}
        kj = jnp.arange(S)[None, :]
        # qi: (t, 1) or (B, t, 1) absolute query positions
        qi = positions[..., :, None]
        if window is None:
            valid = kj[None] <= qi if qi.ndim == 3 else kj <= qi
        else:
            # ring buffer: slot j holds the newest position p ≡ j (mod S);
            # valid iff 0 <= p and within the window
            kj_b = kj[None] if qi.ndim == 3 else kj
            slot_pos = qi - ((qi - kj_b) % S)
            valid = (slot_pos >= 0) & (slot_pos > qi - window)
        # -> broadcastable to (B?, 1, 1, t, S)
        mask = valid[:, None, None] if valid.ndim == 3 else valid[None, None, None]
        out = gqa_scores_softmax_value(q, ck, cv, mask, q_per_kv=spec.num_heads // spec.num_kv_heads)
    else:
        t, s = q.shape[1], k.shape[1]
        qpk = spec.num_heads // spec.num_kv_heads
        if attn_chunk is not None and t > attn_chunk and t % attn_chunk == 0:
            out = chunked_causal_attention(
                q, k, v, q_per_kv=qpk, causal=causal, window=window,
                chunk=attn_chunk, causal_skip=causal_skip,
            )
        else:
            if causal:
                mask = causal_mask(t, s, window=window)
            else:
                mask = jnp.ones((1, 1, 1, t, s), bool)
            out = gqa_scores_softmax_value(q, k, v, mask, q_per_kv=qpk)
        new_cache = {"k": k, "v": v}

    if sharder is not None:
        out = sharder.constrain(out, ["batch", None, "model", None])
    y = jnp.einsum("bthk,hkd->btd", out, _cast(p["wo"], dtype))
    if sharder is not None:
        y = sharder.act_btd(y)
    return y, new_cache


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, kind, dtype):
    ks = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    if kind == "swiglu":
        return {
            "w_gate": jax.random.normal(ks[0], (d_model, d_ff), dtype) * s_in,
            "w_up": jax.random.normal(ks[1], (d_model, d_ff), dtype) * s_in,
            "w_down": jax.random.normal(ks[2], (d_ff, d_model), dtype) * s_out,
        }
    if kind in ("relu2", "gelu"):  # relu2: nemotron-4; gelu: whisper
        return {
            "w_up": jax.random.normal(ks[0], (d_model, d_ff), dtype) * s_in,
            "w_down": jax.random.normal(ks[1], (d_ff, d_model), dtype) * s_out,
        }
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp_apply(p, x, kind, dtype, sharder=None):
    if kind == "swiglu":
        h = jax.nn.silu(x @ _cast(p["w_gate"], dtype)) * (x @ _cast(p["w_up"], dtype))
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(x @ _cast(p["w_up"], dtype)))
    elif kind == "gelu":
        h = jax.nn.gelu(x @ _cast(p["w_up"], dtype))
    else:
        raise ValueError(kind)
    if sharder is not None:
        h = sharder.constrain(h, ["batch", "seq", "model"])
    y = h @ _cast(p["w_down"], dtype)
    if sharder is not None:
        y = sharder.act_btd(y)
    return y


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------


def embedding_init(key, vocab, d_model, dtype):
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embed(p, tokens, dtype):
    return _cast(p["table"], dtype)[tokens]


def unembed(p_head, x, dtype):
    """x (b, t, d) -> logits (b, t, V); head weight (d, V) vocab-parallel."""
    return x @ _cast(p_head["w"], dtype)


def cross_entropy(logits, labels, *, z_loss: float = 0.0):
    """Mean token cross-entropy in fp32; labels -100 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    safe_labels = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
    loss = (logz - gold) * valid
    if z_loss:
        loss = loss + z_loss * jnp.square(logz) * valid
    denom = jnp.maximum(valid.sum(), 1)
    return loss.sum() / denom
