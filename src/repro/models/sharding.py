"""Divisibility-aware sharding rules.

Real fleets are not uniform: 20-head models meet 16-way tensor-parallel
meshes, 60-expert MoEs meet 16-way expert-parallel axes, 51866-token vocabs
meet power-of-two grids.  Rather than padding models to fit the mesh (which
corrupts the roofline accounting), every rule here degrades gracefully:
a dim is sharded over an axis set only if its size divides the axis product,
otherwise the next fallback (or replication) applies.  The dry-run prints
what actually sharded, so EXPERIMENTS.md records the truth.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ShardingPlan


def axis_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


class Sharder:
    """Builds PartitionSpecs from logical dim rules against a concrete mesh.

    A *rule* for one dim is a tuple of logical names, tried in order:
      - "batch"  -> plan.batch_axes present in the mesh (pod+data)
      - "fsdp"   -> plan.fsdp_axes if plan.fsdp (ZeRO-style weight shard)
      - "model"  -> plan.model_axis
      - "seq"    -> model axis if plan.seq_shard (sequence parallelism)
      - None     -> replicate
    The first candidate whose axis product divides the dim size wins.
    """

    def __init__(self, mesh: Mesh, plan: ShardingPlan):
        self.mesh = mesh
        self.plan = plan
        present = set(mesh.axis_names)
        self._batch = tuple(a for a in plan.batch_axes if a in present)
        self._fsdp = (
            tuple(a for a in plan.fsdp_axes if a in present) if plan.fsdp else ()
        )
        self._model = (plan.model_axis,) if plan.model_axis in present else ()
        if plan.pod_in_model and "pod" in present:
            self._model = ("pod",) + self._model
            self._batch = tuple(a for a in self._batch if a != "pod")
        self._seq = self._model if plan.seq_shard else ()

    def _resolve(self, logical) -> tuple:
        if logical is None:
            return ()
        out = []
        for name in (logical if isinstance(logical, (tuple, list)) else (logical,)):
            if name == "batch":
                out.extend(self._batch)
            elif name == "fsdp":
                out.extend(self._fsdp)
            elif name == "model":
                out.extend(self._model)
            elif name == "seq":
                out.extend(self._seq)
            else:  # raw mesh axis name
                if name in self.mesh.axis_names:
                    out.append(name)
        return tuple(out)

    def dim_spec(self, size: int, *candidates):
        """First candidate whose mesh-axis product divides ``size``."""
        for cand in candidates:
            axes = self._resolve(cand)
            if not axes:
                continue
            if size % axis_size(self.mesh, axes) == 0:
                return axes if len(axes) > 1 else axes[0]
        return None

    def spec(self, shape, rules) -> P:
        """``rules``: per-dim tuple of candidate lists (or a single logical
        name, or None).  Shorter rules are right-padded with None."""
        dims = []
        used: set = set()
        for i, size in enumerate(shape):
            rule = rules[i] if i < len(rules) else None
            if rule is None:
                dims.append(None)
                continue
            cands = rule if isinstance(rule, list) else [rule]
            picked = self.dim_spec(size, *cands)
            # one mesh axis may appear once per spec
            flat = (
                tuple(picked)
                if isinstance(picked, tuple)
                else ((picked,) if picked else ())
            )
            if any(a in used for a in flat):
                dims.append(None)
                continue
            used.update(flat)
            dims.append(picked)
        return P(*dims)

    def named(self, shape, rules) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, rules))

    def constrain(self, x, rules):
        """with_sharding_constraint against this mesh (no-op off-mesh dims)."""
        spec = self.spec(x.shape, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    # convenience: common activation layouts ------------------------------

    def act_btd(self, x):
        """(batch, seq, d_model): batch over data axes, optionally seq-shard."""
        return self.constrain(x, ["batch", "seq", None])

    def act_bt(self, x):
        return self.constrain(x, ["batch", "seq"])

    def logits(self, x):
        """(batch, seq, vocab): vocab over model axis (vocab-parallel head)."""
        return self.constrain(x, ["batch", None, "model"])


def tree_spec(sharder: Sharder, params, rules_tree) -> dict:
    """Map a rules pytree over a params pytree -> PartitionSpec pytree."""
    return jax.tree_util.tree_map(
        lambda p, r: sharder.spec(p.shape, r),
        params,
        rules_tree,
        is_leaf=lambda x: isinstance(x, (list, tuple)) and not isinstance(x[0], (list, tuple, type(None), str)),
    )
