"""Unified model API: every assigned architecture behind one surface.

``build_model(cfg)`` returns a :class:`Model` whose members are pure
functions — ready for ``jax.jit`` with explicit shardings (dry-run), the
training loop, and the serving engine's device handler table (prefill and
decode registered as HAM device handlers sharing the cache payload spec).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models import whisper as W
from repro.models import xlstm as X
from repro.models import zamba2 as Z
from repro.models.config import ModelConfig, ShapeCell


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    loss: Callable           # (params, batch, sharder=None) -> (loss, metrics)
    forward: Callable        # (params, batch, sharder=None) -> logits
    prefill: Callable        # (params, batch, sharder=None) -> (logits, cache)
    decode_step: Callable    # (params, cache, batch, sharder=None) -> (logits, cache)
    init_cache: Callable     # (batch_size, max_len, window=None) -> cache
    param_rules: Callable    # () -> rules pytree (Sharder format)
    cache_rules: Callable    # () -> rules pytree for the cache
    input_specs: Callable    # (cell) -> batch pytree of ShapeDtypeStruct
    has_decode: bool = True


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _token_specs(cfg: ModelConfig, cell: ShapeCell):
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        batch = {"tokens": _i32((B, S)), "labels": _i32((B, S))}
    elif cell.kind == "prefill":
        batch = {"tokens": _i32((B, S))}
    else:  # decode: one new token, cache covers seq_len
        batch = {"tokens": _i32((B, 1)), "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.vlm is not None and cell.kind != "decode":
        n_text = S - cfg.vlm.num_patches
        batch["tokens"] = _i32((B, n_text))
        if "labels" in batch:
            batch["labels"] = _i32((B, n_text))
        batch["patch_embeds"] = _f32((B, cfg.vlm.num_patches, cfg.d_model))
    if cfg.encdec is not None and cell.kind != "decode":
        batch["frames"] = _f32((B, cfg.encdec.encoder_frames, cfg.d_model))
    return batch


def _generic_loss(forward_fn):
    def loss(params, batch, sharder=None, aux_weight=0.01):
        logits, _, aux = forward_fn(params, batch, sharder=sharder)
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:  # vision prefix (VLM)
            pad = jnp.full(
                (labels.shape[0], logits.shape[1] - labels.shape[1]),
                -100, labels.dtype,
            )
            labels = jnp.concatenate([pad, labels], axis=1)
        ce = L.cross_entropy(logits, labels)
        return ce + aux_weight * aux, {"ce": ce, "aux": aux}

    return loss


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        fwd = lambda p, b, sharder=None: T.lm_forward(p, b, cfg, sharder=sharder)

        def prefill(p, b, sharder=None):
            logits, cache, _ = T.lm_forward(p, b, cfg, sharder=sharder,
                                            return_cache=True)
            return logits, cache

        return Model(
            cfg=cfg,
            init=lambda key: T.lm_init(key, cfg),
            loss=_generic_loss(fwd),
            forward=lambda p, b, sharder=None: fwd(p, b, sharder)[0],
            prefill=prefill,
            decode_step=lambda p, c, b, sharder=None: T.lm_decode_step(
                p, c, b, cfg, sharder=sharder),
            init_cache=lambda bs, ml, window=None: T.lm_init_cache(
                cfg, bs, ml, window=window),
            param_rules=lambda: T.lm_param_rules(cfg),
            cache_rules=lambda: T.lm_cache_rules(cfg),
            input_specs=lambda cell: _token_specs(cfg, cell),
        )

    if cfg.family == "ssm":  # xLSTM
        fwd = lambda p, b, sharder=None: X.xlstm_forward(p, b, cfg, sharder=sharder)

        def prefill(p, b, sharder=None):
            logits, states, _ = X.xlstm_forward(p, b, cfg, sharder=sharder,
                                                return_cache=True)
            mst, sst = states
            return logits, {"mlstm": mst, "slstm": sst}

        def cache_rules():
            m_rule = (
                [None, None, "batch", None, "model", None],   # C
                [None, None, "batch", None, "model"],         # n
                [None, None, "batch", None],                  # m
                [None, None, "batch", None, "model"],         # conv
            )
            s_rule = (
                [None, None, "batch", "model"],
                [None, None, "batch", "model"],
                [None, None, "batch", "model"],
                [None, None, "batch", "model"],
                [None, None, "batch", None, "model"],
            )
            return {"mlstm": m_rule, "slstm": s_rule}

        return Model(
            cfg=cfg,
            init=lambda key: X.xlstm_init(key, cfg),
            loss=_generic_loss(fwd),
            forward=lambda p, b, sharder=None: fwd(p, b, sharder)[0],
            prefill=prefill,
            decode_step=lambda p, c, b, sharder=None: X.xlstm_decode_step(
                p, c, b, cfg, sharder=sharder),
            init_cache=lambda bs, ml, window=None: X.xlstm_init_cache(cfg, bs, ml),
            param_rules=lambda: X.xlstm_param_rules(cfg),
            cache_rules=cache_rules,
            input_specs=lambda cell: _token_specs(cfg, cell),
        )

    if cfg.family == "hybrid":  # zamba2
        fwd = lambda p, b, sharder=None: Z.zamba2_forward(p, b, cfg, sharder=sharder)

        def prefill(p, b, sharder=None):
            logits, states, _ = Z.zamba2_forward(p, b, cfg, sharder=sharder,
                                                 return_cache=True)
            mst, kv = states
            return logits, {"mamba": mst, "attn_kv": kv}

        def cache_rules():
            return {
                "mamba": (
                    [None, None, "batch", "model", None, None],  # h
                    [None, None, "batch", None, "model"],        # conv
                ),
                "attn_kv": {
                    "k": [None, "batch", None, "model", None],
                    "v": [None, "batch", None, "model", None],
                },
            }

        return Model(
            cfg=cfg,
            init=lambda key: Z.zamba2_init(key, cfg),
            loss=_generic_loss(fwd),
            forward=lambda p, b, sharder=None: fwd(p, b, sharder)[0],
            prefill=prefill,
            decode_step=lambda p, c, b, sharder=None: Z.zamba2_decode_step(
                p, c, b, cfg, sharder=sharder),
            init_cache=lambda bs, ml, window=None: Z.zamba2_init_cache(
                cfg, bs, ml, window=window),
            param_rules=lambda: Z.zamba2_param_rules(cfg),
            cache_rules=cache_rules,
            input_specs=lambda cell: _token_specs(cfg, cell),
        )

    if cfg.family == "audio":  # whisper enc-dec
        fwd = lambda p, b, sharder=None: W.whisper_forward(p, b, cfg, sharder=sharder)

        def prefill(p, b, sharder=None):
            logits, caches, _ = W.whisper_forward(p, b, cfg, sharder=sharder,
                                                  return_cache=True)
            self_c, cross_c = caches
            return logits, {"self": self_c, "cross": cross_c}

        def cache_rules():
            # kv=20 doesn't divide the 16-way model axis -> shard cache seq
            # (self: 32k ✓); cross cache frames=1500 falls back to replicate
            kv = {"k": [None, "batch", ["model"], None, None],
                  "v": [None, "batch", ["model"], None, None]}
            return {"self": kv, "cross": kv}

        return Model(
            cfg=cfg,
            init=lambda key: W.whisper_init(key, cfg),
            loss=_generic_loss(fwd),
            forward=lambda p, b, sharder=None: fwd(p, b, sharder)[0],
            prefill=prefill,
            decode_step=lambda p, c, b, sharder=None: W.whisper_decode_step(
                p, c, b, cfg, sharder=sharder),
            init_cache=lambda bs, ml, window=None: W.whisper_init_cache(cfg, bs, ml),
            param_rules=lambda: W.whisper_param_rules(cfg),
            cache_rules=cache_rules,
            input_specs=lambda cell: _token_specs(cfg, cell),
        )

    raise ValueError(f"unknown family {cfg.family!r}")
