"""Mixture-of-Experts layer: top-k routing with grouped, capacity-bounded
dispatch (sort-based, gather-only — the GSPMD-friendly formulation).

Design notes (these choices are what the roofline sees):

* Tokens are split into **groups** (~``tokens_per_group`` each).  Routing,
  sorting and capacity are per-group, so the sort is local to a data shard
  and the dispatched tensor ``xe`` has shape (G, E, C, d) with G sharded
  over the batch axes and E over the model axis (expert parallelism).  The
  group-to-expert resharding is the MoE all-to-all.
* Dispatch/combine are pure **gathers** (argsort + rank arithmetic), never
  scatters — XLA shards gathers well; scatters tend to lower to
  all-gather + select at pod scale.
* Experts compute a SwiGLU at per-expert width; expert weights are read
  once per step (grouped matmul), which is the honest memory cost — the
  Pallas ``grouped_matmul`` kernel mirrors exactly this contraction.
* Capacity overflow drops tokens (contributes zero); the auxiliary
  load-balance loss keeps the router from abusing that.
* ``expert_parallel=False`` (e.g. qwen2-moe's 60 experts on a 16-way model
  axis) shards the expert FFN dim instead — TP-in-expert.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import MoEConfig


def moe_init(key, d_model: int, cfg: MoEConfig, dtype):
    ks = jax.random.split(key, 6)
    E, f = cfg.num_experts, cfg.d_ff_expert
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(f)
    p = {
        "router": jax.random.normal(ks[0], (d_model, E), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (E, d_model, f), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (E, d_model, f), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (E, f, d_model), dtype) * s_out,
    }
    if cfg.num_shared_experts:
        fs = cfg.num_shared_experts * f
        p["shared"] = {
            "w_gate": jax.random.normal(ks[4], (d_model, fs), dtype) * s_in,
            "w_up": jax.random.normal(ks[5], (d_model, fs), dtype) * s_in,
            "w_down": jax.random.normal(ks[0], (fs, d_model), dtype) * s_out,
            "gate": jnp.zeros((d_model, 1), dtype),
        }
    return p


def expert_specs(sharder, cfg: MoEConfig):
    """PartitionSpec rules for the expert stacks (EP or TP-in-expert)."""
    if cfg.expert_parallel:
        return {
            "router": [None, None],
            "w_gate": ["model", ["fsdp"], None],
            "w_up": ["model", ["fsdp"], None],
            "w_down": ["model", None, ["fsdp"]],
        }
    return {
        "router": [None, None],
        "w_gate": [None, ["fsdp"], "model"],
        "w_up": [None, ["fsdp"], "model"],
        "w_down": [None, "model", ["fsdp"]],
    }


def _group_count(num_tokens: int, tokens_per_group: int) -> int:
    g = max(1, num_tokens // max(tokens_per_group, 1))
    while num_tokens % g:
        g -= 1
    return g


def moe_apply(
    p,
    x,
    cfg: MoEConfig,
    dtype,
    *,
    sharder=None,
    tokens_per_group: int = 4096,
):
    """x: (B, T, d) -> (y, aux_loss)."""
    B, T, d = x.shape
    N = B * T
    E, k = cfg.num_experts, cfg.top_k
    G = _group_count(N, tokens_per_group)
    Tg = N // G
    C = int(np.ceil(Tg * k / E * cfg.capacity_factor))

    if Tg <= 256:
        # decode-sized groups: capacity drops would zero a token's MLP
        # entirely (generation-quality disaster) — go dropless: C = Tg
        # guarantees no expert overflows (each token adds at most 1)
        C = Tg

    xf = x.reshape(G, Tg, d)
    if sharder is not None:
        xf = sharder.constrain(xf, ["batch", None, None])

    # --- routing (fp32) ----------------------------------------------------
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                # (G,Tg,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch/OLMoE form)
    density = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * mean_prob)

    # --- sort pairs by expert within each group -----------------------------
    P_ = Tg * k
    pair_e = top_e.reshape(G, P_)                          # (G,P)
    pair_w = top_w.reshape(G, P_)
    sort = jnp.argsort(pair_e, axis=-1, stable=True)       # (G,P) pair ids ordered by expert
    ranks = jnp.argsort(sort, axis=-1)                     # rank of each pair in that order
    counts = jnp.sum(
        jax.nn.one_hot(pair_e, E, dtype=jnp.int32), axis=1
    )                                                      # (G,E)
    offsets = jnp.cumsum(counts, axis=-1) - counts         # (G,E) exclusive
    pos_in_e = ranks - jnp.take_along_axis(offsets, pair_e, axis=-1)  # (G,P)
    keep = pos_in_e < C

    # --- dispatch: slot (g,e,c) <- token of sorted pair offsets[g,e]+c ------
    slot = offsets[:, :, None] + jnp.arange(C)[None, None, :]          # (G,E,C)
    slot_valid = jnp.arange(C)[None, None, :] < jnp.minimum(counts, C)[:, :, None]
    slot_c = jnp.clip(slot, 0, P_ - 1)
    pair_id = jnp.take_along_axis(sort, slot_c.reshape(G, -1), axis=-1).reshape(G, E, C)
    tok_id = pair_id // k                                   # (G,E,C) token within group
    xe = jnp.take_along_axis(
        xf, tok_id.reshape(G, -1)[..., None], axis=1
    ).reshape(G, E, C, d)
    xe = jnp.where(slot_valid[..., None], xe, 0).astype(dtype)
    if sharder is not None:
        if cfg.expert_parallel:
            xe = sharder.constrain(xe, ["batch", "model", None, None])
        else:
            xe = sharder.constrain(xe, ["batch", None, None, None])

    # --- grouped expert SwiGLU (the grouped_matmul kernel's contraction) ----
    wg, wu, wd = (p["w_gate"].astype(dtype), p["w_up"].astype(dtype),
                  p["w_down"].astype(dtype))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, wg)) * jnp.einsum(
        "gecd,edf->gecf", xe, wu
    )
    if sharder is not None:
        if cfg.expert_parallel:
            h = sharder.constrain(h, ["batch", "model", None, None])
        else:
            h = sharder.constrain(h, ["batch", None, None, "model"])
    ye = jnp.einsum("gecf,efd->gecd", h, wd)                # (G,E,C,d)

    # --- combine: gather each pair's slot, weight, sum over k ---------------
    ye_flat = ye.reshape(G, E * C, d)
    pair_slot = jnp.clip(pair_e * C + pos_in_e, 0, E * C - 1)  # (G,P)
    y_pair = jnp.take_along_axis(ye_flat, pair_slot[..., None], axis=1)  # (G,P,d)
    y_pair = y_pair * (keep * pair_w).astype(dtype)[..., None]
    y = y_pair.reshape(G, Tg, k, d).sum(axis=2)             # (G,Tg,d)
    y = y.reshape(B, T, d)

    # --- shared experts (qwen2-moe) ------------------------------------------
    if "shared" in p:
        ps = p["shared"]
        hs = jax.nn.silu(x @ ps["w_gate"].astype(dtype)) * (x @ ps["w_up"].astype(dtype))
        if sharder is not None:
            hs = sharder.constrain(hs, ["batch", "seq", "model"])
        ys = hs @ ps["w_down"].astype(dtype)
        gate = jax.nn.sigmoid((x @ ps["gate"].astype(dtype)).astype(jnp.float32))
        y = y + ys * gate.astype(dtype)

    if sharder is not None:
        y = sharder.act_btd(y)
    return y, aux
