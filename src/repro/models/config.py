"""Model and sharding configuration.

One :class:`ModelConfig` describes any of the assigned architectures; family
subconfigs switch in MoE / xLSTM / SSM / enc-dec / VLM behaviour.  The
:class:`ShardingPlan` is the hillclimb surface for the roofline work: every
perf iteration in EXPERIMENTS.md §Perf is a delta on these fields.
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # EP shards the expert dim over the model axis (needs divisibility);
    # TP-in-expert shards d_ff_expert instead (e.g. qwen2-moe's 60 experts)
    expert_parallel: bool = True


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    # ratio of mLSTM to sLSTM blocks, e.g. 7:1 (xLSTM[7:1] of the paper)
    mlstm_per_group: int = 7
    slstm_per_group: int = 1
    chunk_size: int = 256          # chunkwise-parallel mLSTM chunk length
    proj_factor: float = 2.0       # mLSTM up-projection factor
    qk_factor: float = 0.5         # d_qk = qk_factor * d_inner (xLSTM-7B layout)
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64            # N (ssm_state)
    head_dim: int = 64             # P
    num_groups: int = 1            # B/C groups (GVA-style)
    chunk_size: int = 256
    conv_width: int = 4
    expand: int = 2                # d_inner = expand * d_model
    # hybrid (zamba2): one shared attention block every `attn_every` ssm
    # blocks, attention weights SHARED across all applications
    attn_every: int = 6
    attn_window: int | None = None  # sliding window for long-context cells


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 32
    encoder_frames: int = 1500     # whisper: fixed 30 s -> 1500 frames (stub)


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    num_patches: int = 256         # patch embeddings prepended to text (stub)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    qkv_bias: bool = False
    mlp: str = "swiglu"            # swiglu | relu2
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    xlstm: XLSTMConfig | None = None
    ssm: SSMConfig | None = None
    encdec: EncDecConfig | None = None
    vlm: VLMConfig | None = None
    # numerics
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"
    # perf levers (hillclimbed in §Perf)
    remat: str = "full"            # none | full | dots_saveable
    remat_group: int = 1           # layers per remat block (saves L/g acts)
    attn_impl: str = "ref"         # ref (XLA einsum) | flash (Pallas, TPU)
    attn_chunk: int = 1024         # q-chunked attention above this seq len
    attn_causal_skip: bool = False  # per-chunk growing kv extent (§Perf)
    kv_quant: bool = False          # int8 KV cache with per-vector scales
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def param_count(self) -> int:
        """Total parameters (for MODEL_FLOPS and checkpoint sizing)."""
        from repro.models.counting import count_params

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.counting import count_active_params

        return count_active_params(self)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """How a config maps onto the production mesh.

    Axis names refer to the mesh from ``launch.mesh.make_production_mesh``:
    ``("data", "model")`` single-pod or ``("pod", "data", "model")``
    multi-pod.  The ``pod`` axis, when present, is folded into the batch
    axes (pure DP across pods — minimal inter-pod traffic) unless
    ``pod_in_model`` is set.
    """

    batch_axes: tuple = ("pod", "data")
    model_axis: str = "model"
    # FSDP: additionally shard each weight's largest replicated dim over the
    # batch axes (ZeRO-3 style); required for the 405B/340B configs
    fsdp: bool = False
    fsdp_axes: tuple = ("data",)
    # sequence parallelism: shard activations' seq dim over model axis where
    # attention allows (long-context cells)
    seq_shard: bool = False
    pod_in_model: bool = False
    # gradient all-reduce in lower precision (distributed-optimisation trick)
    grad_reduce_dtype: str | None = None


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (shape) cell: what gets lowered in the dry-run."""

    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_CELLS = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)


def shape_cell(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)


def supports_cell(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(runnable, reason-if-not).  Pure full-attention archs skip long_500k
    (quadratic attention at 524k seq is not meaningfully lowerable); SSM and
    hybrid archs run it (recurrent state decode)."""
    if cell.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §5)"
    return True, ""


def cells_for(cfg: ModelConfig):
    return [(c, *supports_cell(cfg, c)) for c in SHAPE_CELLS]
