"""xLSTM: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scalar memory,
sequential) blocks, arranged mLSTM:sLSTM = 7:1 per group (xLSTM[7:1]).

The mLSTM cell uses exponential gating with the max-stabiliser, computed in
a **chunkwise-parallel** form for train/prefill (matmul-dominated — the
shape the Pallas ``mlstm`` kernel accelerates) and the exact recurrent form
for decode.  Both derive from:

    m_t = max(f̃_t + m_{t-1}, ĩ_t)
    C_t = exp(f̃_t + m_{t-1} - m_t) C_{t-1} + exp(ĩ_t - m_t) k_t v_tᵀ
    n_t = exp(f̃_t + m_{t-1} - m_t) n_{t-1} + exp(ĩ_t - m_t) k_t
    h_t = (q_tᵀ C_t) / max(|q_tᵀ n_t|, exp(-m_t)),   q scaled by 1/√dk

Chunk form (within a chunk, F_t = Σ_{s≤t} f̃_s, a_s = ĩ_s − F_s,
g_t = max(m_prev, cummax_{s≤t} a_s)):  the (t,s) attention-like weight is
exp(a_s − g_t) — F_t cancels — so one chunk is two matmuls plus elementwise
gates, and the inter-chunk state carries (C, n, m).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig


# --------------------------------------------------------------------------
# causal conv1d (width-w depthwise), with streaming state for decode
# --------------------------------------------------------------------------


def causal_conv_init(key, width, channels, dtype):
    return {"w": jax.random.normal(key, (width, channels), dtype) * (1.0 / np.sqrt(width))}


def causal_conv(p, x, dtype):
    """x: (B, S, C) -> same shape; causal depthwise conv."""
    w = p["w"].astype(dtype)
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    return sum(
        xp[:, i : i + x.shape[1], :] * w[i] for i in range(width)
    )


def causal_conv_step(p, x_t, conv_state, dtype):
    """x_t: (B, 1, C); conv_state: (B, width-1, C) past inputs."""
    w = p["w"].astype(dtype)
    width = w.shape[0]
    window = jnp.concatenate([conv_state, x_t], axis=1)  # (B, width, C)
    out = jnp.einsum("bwc,wc->bc", window, w)[:, None, :]
    return out, window[:, 1:, :]


# --------------------------------------------------------------------------
# mLSTM cell
# --------------------------------------------------------------------------


def mlstm_chunked(q, k, v, i_pre, f_pre, state=None, *, chunk: int):
    """Chunkwise-parallel mLSTM.

    q,k,v: (B, S, H, dk|dv); i_pre/f_pre: (B, S, H) raw gate pre-activations.
    state: optional (C (B,H,dk,dv), n (B,H,dk), m (B,H)).
    Returns (h (B,S,H,dv), final_state).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    q = q / np.sqrt(dk)
    nc = S // chunk
    assert S % chunk == 0, "sequence must be divisible by chunk"
    # (B, H, nc, L, ...)
    qc = q.reshape(B, nc, chunk, H, dk).transpose(0, 3, 1, 2, 4)
    kc = k.reshape(B, nc, chunk, H, dk).transpose(0, 3, 1, 2, 4)
    vc = v.reshape(B, nc, chunk, H, dv).transpose(0, 3, 1, 2, 4)
    ic = i_pre.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2).astype(jnp.float32)
    fc = jax.nn.log_sigmoid(
        f_pre.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2).astype(jnp.float32)
    )

    F = jnp.cumsum(fc, axis=-1)                      # (B,H,nc,L)
    a = ic - F                                        # log source weights
    a_cmax = jax.lax.cummax(a, axis=a.ndim - 1)

    if state is None:
        C0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = (s.astype(jnp.float32) for s in state)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_body(carry, xs):
        C, n, m = carry
        qi, ki, vi, Fi, ai, acm = xs  # (B,H,L,*) for this chunk
        g = jnp.maximum(m[..., None], acm)            # (B,H,L)
        # intra-chunk
        w_ts = jnp.exp(ai[..., None, :] - g[..., :, None])  # (B,H,L,L): exp(a_s - g_t)
        scores = jnp.einsum("bhtk,bhsk->bhts", qi.astype(jnp.float32), ki.astype(jnp.float32))
        Smat = jnp.where(tri, scores * w_ts, 0.0)
        num = jnp.einsum("bhts,bhsv->bhtv", Smat, vi.astype(jnp.float32))
        den = Smat.sum(-1)
        # inter-chunk
        scale = jnp.exp(m[..., None] - g)             # (B,H,L)
        qC = jnp.einsum("bhtk,bhkv->bhtv", qi.astype(jnp.float32), C)
        qn = jnp.einsum("bhtk,bhk->bht", qi.astype(jnp.float32), n)
        num = num + scale[..., None] * qC
        den = den + scale * qn
        m_t = Fi + g
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update (end of chunk)
        gL = g[..., -1]
        FL = Fi[..., -1]
        decay_src = jnp.exp(ai - gL[..., None])       # (B,H,L)
        C_new = jnp.exp(m - gL)[..., None, None] * C + jnp.einsum(
            "bhs,bhsk,bhsv->bhkv", decay_src, ki.astype(jnp.float32), vi.astype(jnp.float32)
        )
        n_new = jnp.exp(m - gL)[..., None] * n + jnp.einsum(
            "bhs,bhsk->bhk", decay_src, ki.astype(jnp.float32)
        )
        m_new = FL + gL
        return (C_new, n_new, m_new), h

    xs = (
        qc.transpose(2, 0, 1, 3, 4), kc.transpose(2, 0, 1, 3, 4),
        vc.transpose(2, 0, 1, 3, 4), F.transpose(2, 0, 1, 3),
        a.transpose(2, 0, 1, 3), a_cmax.transpose(2, 0, 1, 3),
    )
    (C, n, m), hs = jax.lax.scan(chunk_body, (C0, n0, m0), xs)
    # hs: (nc, B, H, L, dv) -> (B, S, H, dv)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dv)
    return h.astype(v.dtype), (C, n, m)


def mlstm_step(q, k, v, i_pre, f_pre, state):
    """Exact recurrent step.  q,k,v: (B,1,H,d*); gates (B,1,H)."""
    B, _, H, dk = q.shape
    out_dtype = v.dtype
    q = (q[:, 0] / np.sqrt(dk)).astype(jnp.float32)
    k = k[:, 0].astype(jnp.float32)
    v = v[:, 0].astype(jnp.float32)
    i_t = i_pre[:, 0].astype(jnp.float32)
    f_t = jax.nn.log_sigmoid(f_pre[:, 0].astype(jnp.float32))
    C, n, m = (s.astype(jnp.float32) for s in state)
    m_new = jnp.maximum(f_t + m, i_t)
    fp = jnp.exp(f_t + m - m_new)
    ip = jnp.exp(i_t - m_new)
    C_new = fp[..., None, None] * C + ip[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n_new = fp[..., None] * n + ip[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C_new)
    den = jnp.einsum("bhk,bhk->bh", q, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h[:, None].astype(out_dtype), (C_new, n_new, m_new)


def mlstm_recurrent(q, k, v, i_pre, f_pre, state=None):
    """Oracle: full recurrence via scan over time (tests compare chunked
    against this)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = (
            jnp.zeros((B, H, dk, dv), jnp.float32),
            jnp.zeros((B, H, dk), jnp.float32),
            jnp.full((B, H), -jnp.inf, jnp.float32),
        )

    def body(st, xs):
        qt, kt, vt, it, ft = xs
        h, st = mlstm_step(qt[:, None], kt[:, None], vt[:, None],
                           it[:, None], ft[:, None], st)
        return st, h[:, 0]

    xs = tuple(arr.transpose(1, 0, *range(2, arr.ndim))
               for arr in (q, k, v, i_pre, f_pre))
    state, hs = jax.lax.scan(body, state, xs)
    return hs.transpose(1, 0, 2, 3), state


# --------------------------------------------------------------------------
# mLSTM block
# --------------------------------------------------------------------------


def mlstm_block_init(key, cfg: ModelConfig):
    x = cfg.xlstm
    d = cfg.d_model
    di = int(x.proj_factor * d)
    dqk = int(x.qk_factor * di)
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    s = 1.0 / np.sqrt(d)
    si = 1.0 / np.sqrt(di)
    return {
        "ln": L.rmsnorm_init(d, dt),
        "w_up": jax.random.normal(ks[0], (d, 2 * di), dt) * s,
        "conv": causal_conv_init(ks[1], x.conv_width, di, dt),
        "wq": jax.random.normal(ks[2], (di, dqk), dt) * si,
        "wk": jax.random.normal(ks[3], (di, dqk), dt) * si,
        "wv": jax.random.normal(ks[4], (di, di), dt) * si,
        "w_if": jax.random.normal(ks[5], (di, 2 * H), dt) * si,
        "b_if": jnp.concatenate([jnp.zeros((H,), dt),
                                 jnp.linspace(3.0, 6.0, H).astype(dt)]),
        "out_norm": L.rmsnorm_init(di, dt),
        "w_down": jax.random.normal(ks[6], (di, d), dt) * si,
    }


def mlstm_block_apply(p, x, cfg: ModelConfig, *, state=None, sharder=None,
                      decode=False):
    """Returns (y, new_state); state = (C, n, m, conv_state)."""
    xl = cfg.xlstm
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    di = int(xl.proj_factor * d)
    dqk = int(xl.qk_factor * di)
    H = cfg.num_heads
    dh = di // H
    dk = dqk // H
    B, S, _ = x.shape

    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    up = h @ p["w_up"].astype(dt)
    xi, z = jnp.split(up, 2, axis=-1)
    if sharder is not None:
        xi = sharder.constrain(xi, ["batch", None, "model"])
        z = sharder.constrain(z, ["batch", None, "model"])

    if decode:
        C, n, m, conv_state = state
        xc, conv_state = causal_conv_step(p["conv"], xi, conv_state, dt)
    else:
        conv_state = None
        if state is not None:
            C, n, m, conv_state = state
        else:
            C = n = m = None
        xc = causal_conv(p["conv"], xi, dt)
    xc = jax.nn.silu(xc)

    q = (xc @ p["wq"].astype(dt)).reshape(B, S, H, dk)
    k = (xc @ p["wk"].astype(dt)).reshape(B, S, H, dk)
    v = (xi @ p["wv"].astype(dt)).reshape(B, S, H, dh)
    gates = xc @ p["w_if"].astype(dt) + p["b_if"].astype(dt)
    i_pre, f_pre = jnp.split(gates.reshape(B, S, 2 * H), 2, axis=-1)

    if decode:
        hcell, (C, n, m) = mlstm_step(q, k, v, i_pre, f_pre, (C, n, m))
    else:
        cell_state = None if C is None else (C, n, m)
        chunk = min(xl.chunk_size, S)
        while S % chunk:
            chunk -= 1
        hcell, (C, n, m) = mlstm_chunked(
            q, k, v, i_pre, f_pre, cell_state, chunk=chunk
        )

    hflat = hcell.reshape(B, S, di)
    hflat = L.rmsnorm(p["out_norm"], hflat, cfg.norm_eps)
    y = (hflat * jax.nn.silu(z)) @ p["w_down"].astype(dt)
    if sharder is not None:
        y = sharder.act_btd(y)
    if decode:
        new_state = (C, n, m, conv_state)
    else:
        width = xl.conv_width
        tail = xi[:, -(width - 1):, :]
        pad = jnp.zeros((B, max(0, width - 1 - S), di), dt)
        new_state = (C, n, m, jnp.concatenate([pad, tail], axis=1))
    return x + y, new_state


def mlstm_state_init(cfg: ModelConfig, batch: int):
    xl = cfg.xlstm
    d = cfg.d_model
    di = int(xl.proj_factor * d)
    H = cfg.num_heads
    dh = di // H
    dk = int(xl.qk_factor * di) // H
    return (
        jnp.zeros((batch, H, dk, dh), jnp.float32),
        jnp.zeros((batch, H, dk), jnp.float32),
        jnp.full((batch, H), -1e30, jnp.float32),
        jnp.zeros((batch, xl.conv_width - 1, di), jnp.dtype(cfg.dtype)),
    )


# --------------------------------------------------------------------------
# sLSTM block (sequential scan; block-diagonal per-head recurrence)
# --------------------------------------------------------------------------


def slstm_block_init(key, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    s = 1.0 / np.sqrt(d)
    ffs = int(4 * d / 3)
    return {
        "ln": L.rmsnorm_init(d, dt),
        "conv": causal_conv_init(ks[0], cfg.xlstm.conv_width, d, dt),
        "w_gates": jax.random.normal(ks[1], (d, 4 * d), dt) * s,   # i,f,z,o
        "r_gates": jax.random.normal(ks[2], (4, H, dh, dh), dt) * (1.0 / np.sqrt(dh)),
        "b_gates": jnp.concatenate([
            jnp.zeros((d,), dt),
            jnp.full((d,), 3.0, dt),            # forget bias
            jnp.zeros((2 * d,), dt),
        ]),
        "out_norm": L.rmsnorm_init(d, dt),
        "w_up": jax.random.normal(ks[3], (d, 2 * ffs), dt) * s,     # GeGLU
        "w_down": jax.random.normal(ks[4], (ffs, d), dt) * (1.0 / np.sqrt(ffs)),
    }


def _slstm_cell(gates_x, hcnm, r_gates):
    """One timestep.  gates_x: (B, 4d) input contribution; state
    (h, c, n, m): each (B, d) [m in fp32]."""
    h, c, n, m = hcnm
    B, d4 = gates_x.shape
    d = d4 // 4
    H, dh = r_gates.shape[1], r_gates.shape[2]
    hh = h.reshape(B, H, dh)
    rec = jnp.einsum("bhk,ghkl->bghl", hh.astype(r_gates.dtype), r_gates)
    rec = rec.reshape(B, 4 * d)
    pre = (gates_x + rec).astype(jnp.float32)
    i_p, f_p, z_p, o_p = jnp.split(pre, 4, axis=-1)
    f_log = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(f_log + m, i_p)
    i_g = jnp.exp(i_p - m_new)
    f_g = jnp.exp(f_log + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z_p)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_p) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new.astype(h.dtype), c_new, n_new, m_new)


def slstm_block_apply(p, x, cfg: ModelConfig, *, state=None, sharder=None,
                      decode=False):
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    B, S, _ = x.shape
    hin = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    if decode:
        (h0, c0, n0, m0, conv_state) = state
        xc, conv_state = causal_conv_step(p["conv"], hin, conv_state, dt)
    else:
        if state is None:
            h0 = jnp.zeros((B, d), dt)
            c0 = jnp.zeros((B, d), jnp.float32)
            n0 = jnp.zeros((B, d), jnp.float32)
            m0 = jnp.full((B, d), -1e30, jnp.float32)
        else:
            h0, c0, n0, m0, _ = state
        xc = causal_conv(p["conv"], hin, dt)
    xc = jax.nn.silu(xc)
    gates_x = xc @ p["w_gates"].astype(dt) + p["b_gates"].astype(dt)

    if decode:
        st = _slstm_cell(gates_x[:, 0], (h0, c0, n0, m0), p["r_gates"])
        hs = st[0][:, None]
        h0, c0, n0, m0 = st
    else:
        def body(carry, g_t):
            st = _slstm_cell(g_t, carry, p["r_gates"])
            return st, st[0]

        (h0, c0, n0, m0), hs = jax.lax.scan(
            body, (h0, c0, n0, m0), gates_x.transpose(1, 0, 2)
        )
        hs = hs.transpose(1, 0, 2)

    hs = L.rmsnorm(p["out_norm"], hs, cfg.norm_eps)
    up = hs @ p["w_up"].astype(dt)
    a, b = jnp.split(up, 2, axis=-1)
    y = (jax.nn.gelu(a) * b) @ p["w_down"].astype(dt)
    if sharder is not None:
        y = sharder.act_btd(y)
    if decode:
        new_state = (h0, c0, n0, m0, conv_state)
    else:
        width = cfg.xlstm.conv_width
        tail = hin[:, -(width - 1):, :]
        pad = jnp.zeros((B, max(0, width - 1 - S), d), dt)
        new_state = (h0, c0, n0, m0, jnp.concatenate([pad, tail], axis=1))
    return x + y, new_state


def slstm_state_init(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    return (
        jnp.zeros((batch, d), dt),
        jnp.zeros((batch, d), jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
        jnp.full((batch, d), -1e30, jnp.float32),
        jnp.zeros((batch, cfg.xlstm.conv_width - 1, d), dt),
    )


# --------------------------------------------------------------------------
# full xLSTM model: groups of (mlstm_per_group mLSTM + slstm_per_group sLSTM)
# --------------------------------------------------------------------------


def _group_counts(cfg: ModelConfig):
    xl = cfg.xlstm
    per = xl.mlstm_per_group + xl.slstm_per_group
    assert cfg.num_layers % per == 0, "num_layers must divide the group size"
    return cfg.num_layers // per, xl.mlstm_per_group, xl.slstm_per_group


def xlstm_init(key, cfg: ModelConfig):
    G, M, Sl = _group_counts(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, G * (M + Sl) + 2)
    ki = iter(keys)
    m_blocks = [[mlstm_block_init(next(ki), cfg) for _ in range(M)] for _ in range(G)]
    s_blocks = [[slstm_block_init(next(ki), cfg) for _ in range(Sl)] for _ in range(G)]
    stack = lambda blocks: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    return {
        "embed": L.embedding_init(next(ki), cfg.vocab_size, cfg.d_model, dt),
        "mlstm": stack([stack(g) for g in m_blocks]),   # leaves (G, M, ...)
        "slstm": stack([stack(g) for g in s_blocks]),   # leaves (G, Sl, ...)
        "final_norm": L.rmsnorm_init(cfg.d_model, dt),
        "head": {"w": jax.random.normal(keys[-1], (cfg.d_model, cfg.vocab_size), dt)
                 * (1.0 / cfg.d_model**0.5)},
    }


def xlstm_forward(p, batch, cfg: ModelConfig, *, sharder=None,
                  return_cache=False):
    dt = jnp.dtype(cfg.dtype)
    x = L.embed(p["embed"], batch["tokens"], dt)
    if sharder is not None:
        x = sharder.act_btd(x)
    B = x.shape[0]

    def m_body(x, layer_p):
        x, st = mlstm_block_apply(layer_p, x, cfg, sharder=sharder)
        return x, st if return_cache else None

    def s_body(x, layer_p):
        x, st = slstm_block_apply(layer_p, x, cfg, sharder=sharder)
        return x, st if return_cache else None

    def group_body(x, group_p):
        mp, sp = group_p
        x, mst = jax.lax.scan(jax.checkpoint(m_body) if cfg.remat != "none" else m_body, x, mp)
        x, sst = jax.lax.scan(jax.checkpoint(s_body) if cfg.remat != "none" else s_body, x, sp)
        return x, (mst, sst)

    x, states = jax.lax.scan(group_body, x, (p["mlstm"], p["slstm"]))
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(p["head"], x, dt)
    if sharder is not None:
        logits = sharder.logits(logits)
    return logits, (states if return_cache else None), jnp.zeros((), jnp.float32)


def xlstm_init_cache(cfg: ModelConfig, batch: int, max_len: int, **_):
    G, M, Sl = _group_counts(cfg)
    rep = lambda st, k: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (G, k) + a.shape).copy(), st
    )
    return {
        "mlstm": rep(mlstm_state_init(cfg, batch), M),
        "slstm": rep(slstm_state_init(cfg, batch), Sl),
    }


def xlstm_decode_step(p, cache, batch, cfg: ModelConfig, *, sharder=None):
    dt = jnp.dtype(cfg.dtype)
    x = L.embed(p["embed"], batch["tokens"], dt)

    def m_body(x, layer_in):
        layer_p, st = layer_in
        x, st = mlstm_block_apply(layer_p, x, cfg, state=st, decode=True,
                                  sharder=sharder)
        return x, st

    def s_body(x, layer_in):
        layer_p, st = layer_in
        x, st = slstm_block_apply(layer_p, x, cfg, state=st, decode=True,
                                  sharder=sharder)
        return x, st

    def group_body(x, group_in):
        mp, mst, sp, sst = group_in
        x, mst = jax.lax.scan(m_body, x, (mp, mst))
        x, sst = jax.lax.scan(s_body, x, (sp, sst))
        return x, (mst, sst)

    x, (mst, sst) = jax.lax.scan(
        group_body, x, (p["mlstm"], cache["mlstm"], p["slstm"], cache["slstm"])
    )
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(p["head"], x, dt)
    if sharder is not None:
        logits = sharder.logits(logits)
    return logits, {"mlstm": mst, "slstm": sst}


def xlstm_param_rules(cfg: ModelConfig):
    mb = {
        "ln": {"scale": [None, None, None]},
        "w_up": [None, None, ["fsdp"], "model"],
        "conv": {"w": [None, None, None, "model"]},
        "wq": [None, None, "model", None],
        "wk": [None, None, "model", None],
        "wv": [None, None, "model", None],
        "w_if": [None, None, "model", None],
        "b_if": [None, None, None],
        "out_norm": {"scale": [None, None, None]},
        "w_down": [None, None, "model", ["fsdp"]],
    }
    sb = {
        "ln": {"scale": [None, None, None]},
        "conv": {"w": [None, None, None, None]},
        "w_gates": [None, None, ["fsdp"], None],
        "r_gates": [None, None, None, None, None, None],
        "b_gates": [None, None, None],
        "out_norm": {"scale": [None, None, None]},
        "w_up": [None, None, ["fsdp"], "model"],
        "w_down": [None, None, "model", ["fsdp"]],
    }
    return {
        "embed": {"table": [["fsdp"], "model"]},
        "mlstm": mb,
        "slstm": sb,
        "final_norm": {"scale": [None]},
        "head": {"w": [["fsdp"], "model"]},
    }
