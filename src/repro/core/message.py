"""Active-message framing.

Paper mapping (§4.3): ``active_msg_base`` — "its only data member is the
globally valid handler key" — becomes a fixed 32-byte little-endian header in
front of the payload.  A received frame is first interpreted as a header (the
cast to ``active_msg_base``); the key then selects the local handler, which
reinterprets the payload according to its registered argument spec (the
upcast into the concrete ``offload_msg<...>`` type).

Header layout (32 bytes, little-endian):

    u32  magic        0x48414D58  ("HAMX")
    u16  version      wire protocol version
    u16  flags        bit0 REPLY, bit1 ERROR, bit2 DYNAMIC payload,
                      bit3 STATIC (plan-packed) payload, bit4 FUSED frame,
                      bit5 RETRYABLE (sender may retransmit; receiver must
                      dedup via its replay cache — docs/failure-model.md)
    u32  key          global handler key (sorted-registry index)
    u32  src_node     sender node id (for replies / reverse offload)
    u64  msg_id       correlates replies with futures
    u64  payload_len  bytes following the header

Payload-format bits (STATIC / DYNAMIC)
--------------------------------------

``FLAG_DYNAMIC`` marks a self-describing TLV payload; ``FLAG_STATIC`` marks
a plan-packed payload whose layout both sides derive from the handler's
registered spec (see ``repro.core.wireplan``).  The bits are *advisory* on
requests — a request with neither bit (a pre-plan peer) dispatches through
the receiver's compiled plan when the handler is static, because the plan
layout is byte-identical to the legacy ``pack_static`` concatenation.  On
**replies** the bit is load-bearing: a reply with ``FLAG_STATIC`` decodes
through the handler's result plan (the key field names the handler), any
other non-error reply decodes as dynamic TLV.  Error replies
(``REPLY|ERROR``) are always dynamic (message + traceback dict).

Fused-frame segment layout (``FLAG_FUSED``)
-------------------------------------------

Small-call fusion packs many sub-threshold calls (or replies) into ONE
frame, amortising the 32-byte header, the per-frame transport publication
and the per-frame dispatch.  The outer header carries ``FLAG_FUSED``,
``key=0``, ``msg_id=0`` and the true ``src_node``; the payload is a count
word followed by back-to-back segments::

    u32 count
    count * ( u32 key | u16 flags | u64 msg_id | u32 payload_len | payload )

Each segment is one complete logical message: its ``flags`` carry the
usual REPLY/ERROR/STATIC/DYNAMIC bits and its payload is exactly what the
equivalent standalone frame would carry after the header.  Segments
default to sharing the outer frame's ``src_node``; a segment whose true
origin differs (a relayed ``_ham/forward`` inner frame folded into the
forwarder's egress batch) instead carries ``FLAG_SEG_SRC`` and prefixes
its payload with a ``u32`` true source node id (see ``docs/transport.md``
— the relayed-fused layout).  The receiver strips the prefix and
dispatches/replies against the embedded source, preserving the forward
contract that the final target answers the *origin* directly.  Segment
order is preserved; a receiver executes request segments in order in a
single dispatch/executor pass, and an error in one segment errors only
that segment's ``msg_id``.

Shape-keyed dynamic payloads (``FLAG_SHAPED``)
----------------------------------------------

``FLAG_SHAPED`` marks a dynamic payload packed through a shape-keyed
cached ``WirePlan`` instead of TLV: the payload is ``u16 sig_len`` +
signature bytes + the plan-packed leaves.  The signature (grammar in
``repro.core.wireplan.spec_signature``) fully determines the plan, so the
receiver compiles-or-looks-up the same plan and unpacks without any
per-leaf TLV interpretation.  Semantically equivalent to FLAG_DYNAMIC —
senders fall back to TLV for shapes the spec grammar cannot express.

Batched-frame segment layout (the coalesced hot path)
-----------------------------------------------------

Transports move frames either one at a time (``send``/``recv``) or
coalesced (``send_many``/``recv_many``).  On the wire a coalesced batch is
simply the concatenation of the per-frame transport encodings — for the shm
ring and the socket stream that is::

    u64 len_0 || frame_0 || u64 len_1 || frame_1 || ... || u64 len_{n-1} || frame_{n-1}

i.e. exactly what ``n`` individual sends would produce, so batching is a
pure *publication* optimisation (one ring-counter store / one syscall per
batch instead of per frame) and the receiver cannot tell — and need not
care — how the sender grouped frames.  ``decode_fast`` is called once per
frame on a zero-copy view into the receive window; ``payload_len`` is
validated against the view so a short/corrupt segment cannot silently
alias a neighbouring frame's bytes.

Zero-copy lifetime rule: payload views returned by :func:`decode_fast` /
:func:`split_frame` alias the frame.  When the frame itself is a leased
transport view (see ``repro.comm.shm``), the view is only valid until the
lease is released — anything that outlives dispatch (futures, retained
arrays) must copy first.
"""

from __future__ import annotations

import dataclasses
import struct

from repro.core.errors import MessageFormatError

# Flag bits live in the centralized wire-constant registry (one table,
# import-time collision assertions, read by the static analyzer) and are
# re-exported here so existing ``from repro.core.message import FLAG_*``
# imports keep working.  Semantics:
#   FLAG_RETRYABLE — request may be retransmitted by the sender (scheduler
#     deadline/retry path): the receiver must dedup on (src_node, msg_id)
#     through its replay cache and resend the cached reply instead of
#     re-executing (docs/failure-model.md).  Meaningless on replies.
#   FLAG_SHAPED — dynamic payload packed via a shape-keyed cached WirePlan:
#     u16 sig_len | signature | plan-packed leaves (repro.core.wireplan).
#   FLAG_SEG_SRC — fused-SEGMENT-only bit: the segment's true origin differs
#     from the outer frame's src_node; payload starts with u32 true src.
from repro.core.flags import (  # noqa: F401  (re-exported wire constants)
    FLAG_DYNAMIC,
    FLAG_ERROR,
    FLAG_FUSED,
    FLAG_REPLY,
    FLAG_RETRYABLE,
    FLAG_SEG_SRC,
    FLAG_SHAPED,
    FLAG_STATIC,
)

MAGIC = 0x48414D58
VERSION = 1
HEADER_STRUCT = struct.Struct("<IHHIIQQ")
HEADER_NBYTES = HEADER_STRUCT.size  # 32

#: fused-frame segment header: key, flags, msg_id, payload_len
SEG_STRUCT = struct.Struct("<IHQI")
SEG_NBYTES = SEG_STRUCT.size  # 18
#: u32 true-source prefix of a FLAG_SEG_SRC segment payload
SEG_SRC_STRUCT = struct.Struct("<I")
SEG_SRC_NBYTES = SEG_SRC_STRUCT.size  # 4
FUSED_COUNT_STRUCT = struct.Struct("<I")


@dataclasses.dataclass(frozen=True)
class Header:
    key: int
    src_node: int
    msg_id: int
    payload_len: int
    flags: int = 0
    version: int = VERSION

    @property
    def is_reply(self) -> bool:
        return bool(self.flags & FLAG_REPLY)

    @property
    def is_error(self) -> bool:
        return bool(self.flags & FLAG_ERROR)

    @property
    def is_dynamic(self) -> bool:
        return bool(self.flags & FLAG_DYNAMIC)

    @property
    def is_static(self) -> bool:
        return bool(self.flags & FLAG_STATIC)

    @property
    def is_fused(self) -> bool:
        return bool(self.flags & FLAG_FUSED)


def encode_header(header: Header, out: bytearray | None = None) -> bytes | bytearray:
    buf = out if out is not None else bytearray(HEADER_NBYTES)
    HEADER_STRUCT.pack_into(
        buf,
        0,
        MAGIC,
        header.version,
        header.flags,
        header.key,
        header.src_node,
        header.msg_id,
        header.payload_len,
    )
    return buf


def decode_header(buf: bytes | bytearray | memoryview) -> Header:
    if len(buf) < HEADER_NBYTES:
        raise MessageFormatError(
            f"frame shorter than header: {len(buf)} < {HEADER_NBYTES}"
        )
    magic, version, flags, key, src_node, msg_id, payload_len = HEADER_STRUCT.unpack_from(
        buf, 0
    )
    if magic != MAGIC:
        raise MessageFormatError(f"bad magic 0x{magic:08x}")
    if version != VERSION:
        raise MessageFormatError(f"unsupported wire version {version}")
    return Header(
        key=key,
        src_node=src_node,
        msg_id=msg_id,
        payload_len=payload_len,
        flags=flags,
        version=version,
    )


def encode_frame(
    key: int,
    payload: bytes | bytearray | memoryview,
    *,
    src_node: int = 0,
    msg_id: int = 0,
    flags: int = 0,
) -> bytearray:
    """One-allocation frame assembly: header || payload."""
    frame = bytearray(HEADER_NBYTES + len(payload))
    HEADER_STRUCT.pack_into(
        frame, 0, MAGIC, VERSION, flags, key, src_node, msg_id, len(payload)
    )
    frame[HEADER_NBYTES:] = payload
    return frame


def decode_fast(frame):
    """Hot-path decode: (key, flags, src_node, msg_id, payload_view) tuple,
    no dataclass allocation.  Validation reduced to the magic check plus a
    payload-length bounds check (a truncated frame must fail loudly here —
    a silently short memoryview would surface as a corrupt argument deep
    inside a handler)."""
    try:
        magic, _version, flags, key, src_node, msg_id, payload_len = (
            HEADER_STRUCT.unpack_from(frame, 0)
        )
    except struct.error as e:
        raise MessageFormatError(f"frame shorter than header: {e}") from None
    if magic != MAGIC:
        raise MessageFormatError(f"bad magic 0x{magic:08x}")
    view = memoryview(frame)
    if view.nbytes - HEADER_NBYTES < payload_len:
        raise MessageFormatError(
            f"truncated frame: header says {payload_len} payload bytes, "
            f"frame carries {view.nbytes - HEADER_NBYTES}"
        )
    return key, flags, src_node, msg_id, view[
        HEADER_NBYTES : HEADER_NBYTES + payload_len
    ]


def iter_fused(payload):
    """Yield ``(key, flags, msg_id, payload_view)`` per fused segment.

    ``payload`` is a fused frame's payload (after the outer header).  Every
    extent is bounds-checked against the enclosing payload — a truncated or
    corrupt segment must fail loudly here, not surface as a garbled argument
    inside a handler.  Segment views alias ``payload`` (zero-copy): the
    caller owns the lifetime rule.
    """
    view = payload if isinstance(payload, memoryview) else memoryview(payload)
    total = view.nbytes
    if total < 4:
        raise MessageFormatError(f"fused payload shorter than count word: {total}")
    (count,) = FUSED_COUNT_STRUCT.unpack_from(view, 0)
    off = 4
    unpack = SEG_STRUCT.unpack_from
    for _ in range(count):
        if off + SEG_NBYTES > total:
            raise MessageFormatError(
                f"truncated fused segment header at offset {off} of {total}"
            )
        key, flags, msg_id, plen = unpack(view, off)
        off += SEG_NBYTES
        if off + plen > total:
            raise MessageFormatError(
                f"truncated fused segment payload: {plen} bytes claimed, "
                f"{total - off} remain"
            )
        yield key, flags, msg_id, view[off : off + plen]
        off += plen
    if off != total:
        raise MessageFormatError(
            f"trailing bytes in fused payload: consumed {off} of {total}"
        )


def split_frame(frame: bytes | bytearray | memoryview) -> tuple[Header, memoryview]:
    """Decode header and return a zero-copy view of the payload."""
    header = decode_header(frame)
    view = memoryview(frame)[HEADER_NBYTES : HEADER_NBYTES + header.payload_len]
    if len(view) != header.payload_len:
        raise MessageFormatError(
            f"truncated payload: header says {header.payload_len}, got {len(view)}"
        )
    return header, view
