"""Active-message framing.

Paper mapping (§4.3): ``active_msg_base`` — "its only data member is the
globally valid handler key" — becomes a fixed 32-byte little-endian header in
front of the payload.  A received frame is first interpreted as a header (the
cast to ``active_msg_base``); the key then selects the local handler, which
reinterprets the payload according to its registered argument spec (the
upcast into the concrete ``offload_msg<...>`` type).

Header layout (32 bytes, little-endian):

    u32  magic        0x48414D58  ("HAMX")
    u16  version      wire protocol version
    u16  flags        bit0 REPLY, bit1 ERROR, bit2 DYNAMIC payload
    u32  key          global handler key (sorted-registry index)
    u32  src_node     sender node id (for replies / reverse offload)
    u64  msg_id       correlates replies with futures
    u64  payload_len  bytes following the header

Batched-frame segment layout (the coalesced hot path)
-----------------------------------------------------

Transports move frames either one at a time (``send``/``recv``) or
coalesced (``send_many``/``recv_many``).  On the wire a coalesced batch is
simply the concatenation of the per-frame transport encodings — for the shm
ring and the socket stream that is::

    u64 len_0 || frame_0 || u64 len_1 || frame_1 || ... || u64 len_{n-1} || frame_{n-1}

i.e. exactly what ``n`` individual sends would produce, so batching is a
pure *publication* optimisation (one ring-counter store / one syscall per
batch instead of per frame) and the receiver cannot tell — and need not
care — how the sender grouped frames.  ``decode_fast`` is called once per
frame on a zero-copy view into the receive window; ``payload_len`` is
validated against the view so a short/corrupt segment cannot silently
alias a neighbouring frame's bytes.

Zero-copy lifetime rule: payload views returned by :func:`decode_fast` /
:func:`split_frame` alias the frame.  When the frame itself is a leased
transport view (see ``repro.comm.shm``), the view is only valid until the
lease is released — anything that outlives dispatch (futures, retained
arrays) must copy first.
"""

from __future__ import annotations

import dataclasses
import struct

from repro.core.errors import MessageFormatError

MAGIC = 0x48414D58
VERSION = 1
HEADER_STRUCT = struct.Struct("<IHHIIQQ")
HEADER_NBYTES = HEADER_STRUCT.size  # 32

FLAG_REPLY = 1 << 0
FLAG_ERROR = 1 << 1
FLAG_DYNAMIC = 1 << 2


@dataclasses.dataclass(frozen=True)
class Header:
    key: int
    src_node: int
    msg_id: int
    payload_len: int
    flags: int = 0
    version: int = VERSION

    @property
    def is_reply(self) -> bool:
        return bool(self.flags & FLAG_REPLY)

    @property
    def is_error(self) -> bool:
        return bool(self.flags & FLAG_ERROR)

    @property
    def is_dynamic(self) -> bool:
        return bool(self.flags & FLAG_DYNAMIC)


def encode_header(header: Header, out: bytearray | None = None) -> bytes | bytearray:
    buf = out if out is not None else bytearray(HEADER_NBYTES)
    HEADER_STRUCT.pack_into(
        buf,
        0,
        MAGIC,
        header.version,
        header.flags,
        header.key,
        header.src_node,
        header.msg_id,
        header.payload_len,
    )
    return buf


def decode_header(buf: bytes | bytearray | memoryview) -> Header:
    if len(buf) < HEADER_NBYTES:
        raise MessageFormatError(
            f"frame shorter than header: {len(buf)} < {HEADER_NBYTES}"
        )
    magic, version, flags, key, src_node, msg_id, payload_len = HEADER_STRUCT.unpack_from(
        buf, 0
    )
    if magic != MAGIC:
        raise MessageFormatError(f"bad magic 0x{magic:08x}")
    if version != VERSION:
        raise MessageFormatError(f"unsupported wire version {version}")
    return Header(
        key=key,
        src_node=src_node,
        msg_id=msg_id,
        payload_len=payload_len,
        flags=flags,
        version=version,
    )


def encode_frame(
    key: int,
    payload: bytes | bytearray | memoryview,
    *,
    src_node: int = 0,
    msg_id: int = 0,
    flags: int = 0,
) -> bytearray:
    """One-allocation frame assembly: header || payload."""
    frame = bytearray(HEADER_NBYTES + len(payload))
    HEADER_STRUCT.pack_into(
        frame, 0, MAGIC, VERSION, flags, key, src_node, msg_id, len(payload)
    )
    frame[HEADER_NBYTES:] = payload
    return frame


def decode_fast(frame):
    """Hot-path decode: (key, flags, src_node, msg_id, payload_view) tuple,
    no dataclass allocation.  Validation reduced to the magic check plus a
    payload-length bounds check (a truncated frame must fail loudly here —
    a silently short memoryview would surface as a corrupt argument deep
    inside a handler)."""
    try:
        magic, _version, flags, key, src_node, msg_id, payload_len = (
            HEADER_STRUCT.unpack_from(frame, 0)
        )
    except struct.error as e:
        raise MessageFormatError(f"frame shorter than header: {e}") from None
    if magic != MAGIC:
        raise MessageFormatError(f"bad magic 0x{magic:08x}")
    view = memoryview(frame)
    if view.nbytes - HEADER_NBYTES < payload_len:
        raise MessageFormatError(
            f"truncated frame: header says {payload_len} payload bytes, "
            f"frame carries {view.nbytes - HEADER_NBYTES}"
        )
    return key, flags, src_node, msg_id, view[
        HEADER_NBYTES : HEADER_NBYTES + payload_len
    ]


def split_frame(frame: bytes | bytearray | memoryview) -> tuple[Header, memoryview]:
    """Decode header and return a zero-copy view of the payload."""
    header = decode_header(frame)
    view = memoryview(frame)[HEADER_NBYTES : HEADER_NBYTES + header.payload_len]
    if len(view) != header.payload_len:
        raise MessageFormatError(
            f"truncated payload: header says {header.payload_len}, got {len(view)}"
        )
    return header, view
