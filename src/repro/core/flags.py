"""Centralized wire-constant registry: every flag bit, sentinel, and width.

Single source of truth for the HAM wire protocol's small-integer namespace.
``core/message.py`` re-exports the ``FLAG_*`` values (callers keep their
existing imports), ``offload/runtime.py`` re-imports the replay-cache
sentinel, and the static analyzer (``repro.analysis``) reads the same
tables — so a new flag that collides with an existing bit, or a sentinel
that drifts into live msg_id space, fails at *import time* here and again
in ``hamlint``'s wire-constant rule, not at 3am in a cross-version fleet.

Three namespaces are declared:

* **Header flag bits** (``FLAG_BITS``): bit positions inside the u16
  ``flags`` header field.  Must be pairwise distinct and < 16.
* **Reserved msg_id sentinels** (``MSG_ID_SENTINELS``): values carved out
  of the u64 msg_id space for control meanings (today: the replay-cache
  FLUSH marker).  Live msg_ids are allocated counting up from 1, so every
  sentinel must sit at or above ``MSG_ID_RESERVED_FLOOR`` — unreachable
  by any realistic allocation (2**56 messages at 10M msg/s is ~228 years).
* **Header field widths** (``HEADER_FIELD_WIDTHS``): the bit width of each
  header field, from which the 32-byte ``<IHHIIQQ`` layout follows.
"""

from __future__ import annotations

# -- header flag bits (positions inside the u16 flags field) ---------------

FLAG_BITS: dict[str, int] = {
    "FLAG_REPLY": 0,      # frame is a reply
    "FLAG_ERROR": 1,      # reply carries an error payload
    "FLAG_DYNAMIC": 2,    # self-describing TLV payload
    "FLAG_STATIC": 3,     # plan-packed payload (repro.core.wireplan)
    "FLAG_FUSED": 4,      # multi-call frame: count word + segments
    "FLAG_RETRYABLE": 5,  # sender may retransmit; receiver must dedup
    "FLAG_SHAPED": 6,     # shape-keyed cached-WirePlan dynamic payload
    "FLAG_SEG_SRC": 7,    # fused segment carries its own u32 src prefix
}

FLAG_REPLY = 1 << FLAG_BITS["FLAG_REPLY"]
FLAG_ERROR = 1 << FLAG_BITS["FLAG_ERROR"]
FLAG_DYNAMIC = 1 << FLAG_BITS["FLAG_DYNAMIC"]
FLAG_STATIC = 1 << FLAG_BITS["FLAG_STATIC"]
FLAG_FUSED = 1 << FLAG_BITS["FLAG_FUSED"]
FLAG_RETRYABLE = 1 << FLAG_BITS["FLAG_RETRYABLE"]
FLAG_SHAPED = 1 << FLAG_BITS["FLAG_SHAPED"]
FLAG_SEG_SRC = 1 << FLAG_BITS["FLAG_SEG_SRC"]

# -- header field widths (bits); layout <IHHIIQQ little-endian -------------

HEADER_FIELD_WIDTHS: dict[str, int] = {
    "magic": 32,
    "version": 16,
    "flags": 16,
    "key": 32,
    "src_node": 32,
    "msg_id": 64,
    "payload_len": 64,
}

FLAGS_FIELD_WIDTH = HEADER_FIELD_WIDTHS["flags"]
MSG_ID_FIELD_WIDTH = HEADER_FIELD_WIDTHS["msg_id"]

# -- reserved msg_id sentinels ---------------------------------------------

#: live msg_ids count up from 1; everything at/above this floor is reserved
#: for control sentinels and can never collide with an allocated id
MSG_ID_RESERVED_FLOOR = 1 << 56

#: replay-cache msg-id-space reset marker (ReplayCache.FLUSH): a retryable
#: frame carrying this id tells the receiver the sender restarted its id
#: counter and the dedup window must be dropped (docs/failure-model.md)
MSG_ID_FLUSH = 1 << 61

MSG_ID_SENTINELS: dict[str, int] = {
    "MSG_ID_FLUSH": MSG_ID_FLUSH,
}

# -- serve/stream status words ---------------------------------------------

#: status word carried by every ``_serve/stream`` token oneway (the
#: worker-driven serving path, docs/serving.md).  A tiny shared namespace
#: like the flag bits: host and workers must agree on these across
#: versions, so they live here, not in the serving modules.  ``TOKEN`` and
#: ``DONE`` messages carry a real token; ``CANCELLED``/``EXPIRED`` are
#: end-of-stream markers whose token field is a placeholder (-1).
SERVE_STREAM_STATUS: dict[str, int] = {
    "STREAM_TOKEN": 0,      # one decoded token, request still running
    "STREAM_DONE": 1,       # final token: the request reached its budget
    "STREAM_CANCELLED": 2,  # request cancelled; slot freed, no token
    "STREAM_EXPIRED": 3,    # request deadline passed; slot freed, no token
}

STREAM_TOKEN = SERVE_STREAM_STATUS["STREAM_TOKEN"]
STREAM_DONE = SERVE_STREAM_STATUS["STREAM_DONE"]
STREAM_CANCELLED = SERVE_STREAM_STATUS["STREAM_CANCELLED"]
STREAM_EXPIRED = SERVE_STREAM_STATUS["STREAM_EXPIRED"]


def _validate() -> None:
    """Import-time collision assertions — the module refuses to load with
    a colliding bit or an out-of-range sentinel."""
    bits = list(FLAG_BITS.values())
    if len(set(bits)) != len(bits):
        dupes = sorted(b for b in set(bits) if bits.count(b) > 1)
        raise AssertionError(f"colliding FLAG_* bit positions: {dupes}")
    for name, bit in FLAG_BITS.items():
        if not 0 <= bit < FLAGS_FIELD_WIDTH:
            raise AssertionError(
                f"{name} bit {bit} outside the u{FLAGS_FIELD_WIDTH} flags field"
            )
    sentinels = list(MSG_ID_SENTINELS.values())
    if len(set(sentinels)) != len(sentinels):
        raise AssertionError("colliding msg_id sentinel values")
    for name, value in MSG_ID_SENTINELS.items():
        if not MSG_ID_RESERVED_FLOOR <= value < (1 << MSG_ID_FIELD_WIDTH):
            raise AssertionError(
                f"{name} = {value:#x} outside the reserved msg_id range "
                f"[{MSG_ID_RESERVED_FLOOR:#x}, 2**{MSG_ID_FIELD_WIDTH})"
            )
    header_bits = sum(HEADER_FIELD_WIDTHS.values())
    if header_bits != 256:
        raise AssertionError(
            f"header field widths sum to {header_bits} bits, expected 256 "
            "(the fixed 32-byte header)"
        )
    statuses = list(SERVE_STREAM_STATUS.values())
    if len(set(statuses)) != len(statuses):
        raise AssertionError("colliding serve-stream status words")


_validate()
