"""Deterministic handler registry — the heart of HAM (paper §4.3, §5.2).

The paper's mechanism:

1. Every active-message type registers its handler during *static
   initialisation* (before ``main()``), keyed by the ``typeid`` mangled name.
2. An explicit ``init()`` call sorts the collected entries by name,
   lexicographically, and assigns the sorted index as the **global handler
   key** — so *all processes derive the identical key map without any
   communication*, as long as they were built from the same source.
3. Sending side: type -> key in O(1) (static member).  Receiving side:
   key -> handler address in O(1) (vector index).  (Fig. 6.)

Python translation:

* "static initialisation"  -> import time; the :func:`handler` decorator
  registers into a module-level pending set.
* ``typeid`` mangled name  -> **stable name** ``module:qualname#spec-digest``.
  The spec digest covers the argument/result specs, mirroring how the C++
  mangled name of ``function<Result(*)(Pars...), FnPtr>`` encodes the
  signature.  Lambdas and closures (``<lambda>`` / ``<locals>`` in the
  qualname) are rejected unless an explicit ``name=`` is supplied — the exact
  caveat the paper hits with compiler-internal lambda names (§5.1), except we
  diagnose it instead of miscompiling.
* ``init()`` -> :meth:`HandlerRegistry.init`, which seals the registry and
  produces the sorted key table plus a **key-map digest** (sha256 over the
  ordered stable names).  The digest lets heterogeneous peers *verify* the
  same-source assumption with one 32-byte compare — the paper merely assumes
  ABI-compatible name mangling; we turn the assumption into a cheap check.

The registry is also re-initialisable with a changed handler set, which is
what makes elastic membership changes cheap at pod scale: a new process
joining a fleet derives the same keys locally, no negotiation (see
``train/ft.py``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
from typing import Any, Callable, Sequence

from repro.core.errors import (
    RegistryError,
    RegistrySealedError,
    UnknownHandlerError,
    UnstableNameError,
)
from repro.core.migratable import Spec, canonical_spec_string, spec_of
from repro.core.wireplan import compile_plan


@dataclasses.dataclass(frozen=True)
class HandlerRecord:
    """One registered handler — the analogue of one ``active_msg`` type.

    ``read_only`` declares that the handler never writes through a
    ``buffer_ptr`` argument (it may read via ``deref``, and may mutate its
    own locals freely).  The declaration is a *routing contract*, not a
    sandbox: a replicated-data-plane scheduler may serve a read-only call
    from ANY replica of its buffers, while a call without the declaration
    has its pointers pinned to the primary copy — so a mutating handler
    can never silently update one replica and diverge the others.  It does
    not participate in the stable name (peers may disagree about it
    without breaking key agreement; routing is a sender-side concern).

    ``mutates`` is the write-side twin (Active Access: ship the mutation
    to the data): the handler *intends* to write through its buffer
    arguments in place.  The scheduler pins such a call to the primary and
    the data plane **commits** the write on return — the buffer's dirty
    epoch is bumped and replica holders are invalidated/refreshed, so the
    mutation becomes visible cluster-wide without the host round-tripping
    the bytes (docs/failure-model.md, "Write visibility and convergence").
    Like ``read_only`` it is routing metadata, excluded from the stable
    name.  The two are mutually exclusive.
    """

    stable_name: str
    fn: Callable
    arg_specs: tuple | None      # None => dynamic (self-describing) payload
    result_specs: tuple | None   # None => dynamic result
    doc: str = ""
    read_only: bool = False
    mutates: bool = False

    @property
    def is_static(self) -> bool:
        return self.arg_specs is not None


class HandlerTable:
    """Sealed, initialised key<->handler mapping (paper Fig. 6).

    * ``key_of``   : type -> key, O(1)  (sending side)
    * ``handler_at``: key -> handler, O(1) list index (receiving side)

    Init also *compiles* the wire plans (``repro.core.wireplan``): for every
    static-spec handler, ``arg_plans[key]`` / ``result_plans[key]`` hold the
    precompiled payload codec (fused scalar struct, fixed array extents,
    exact ``payload_nbytes``); dynamic sides hold ``None``.  The dense
    key-indexed arrays are what the runtime hot path dispatches off —
    no per-message record attribute walks.
    """

    def __init__(self, records: Sequence[HandlerRecord]):
        ordered = sorted(records, key=lambda r: r.stable_name)
        self._records: list[HandlerRecord] = list(ordered)
        #: key-indexed views for the runtime hot path (records is the same
        #: list handler_at indexes; plans are compiled once, here)
        self.records: list[HandlerRecord] = self._records
        self.arg_plans = [compile_plan(r.arg_specs) for r in ordered]
        self.result_plans = [compile_plan(r.result_specs) for r in ordered]
        self._key_by_name: dict[str, int] = {
            r.stable_name: i for i, r in enumerate(ordered)
        }
        # base-name aliases (stable name minus the spec digest) where
        # unambiguous — convenience lookup, never used for key derivation
        base_counts: dict[str, int] = {}
        for r in ordered:
            base = r.stable_name.rsplit("#", 1)[0]
            base_counts[base] = base_counts.get(base, 0) + 1
        for i, r in enumerate(ordered):
            base = r.stable_name.rsplit("#", 1)[0]
            if base_counts[base] == 1 and base not in self._key_by_name:
                self._key_by_name[base] = i
        self._key_by_fn: dict[Any, int] = {r.fn: i for i, r in enumerate(ordered)}
        h = hashlib.sha256()
        for r in ordered:
            h.update(r.stable_name.encode("utf-8"))
            h.update(b"\x00")
        self.digest: bytes = h.digest()

    def __len__(self) -> int:
        return len(self._records)

    def __iter__(self):
        return iter(self._records)

    def key_of(self, fn_or_name) -> int:
        if isinstance(fn_or_name, str):
            try:
                return self._key_by_name[fn_or_name]
            except KeyError:
                raise UnknownHandlerError(f"no handler named {fn_or_name!r}") from None
        try:
            return self._key_by_fn[fn_or_name]
        except (KeyError, TypeError):
            raise UnknownHandlerError(
                f"function {getattr(fn_or_name, '__qualname__', fn_or_name)!r} is "
                "not a registered handler; decorate it with @ham.handler"
            ) from None

    def handler_at(self, key: int) -> HandlerRecord:
        if not 0 <= key < len(self._records):
            raise UnknownHandlerError(
                f"handler key {key} outside local table of size {len(self._records)}; "
                "peer key maps diverge (same-source assumption violated)"
            )
        return self._records[key]

    def record_of(self, fn_or_name) -> HandlerRecord:
        return self._records[self.key_of(fn_or_name)]

    def dump(self) -> str:
        """Human-readable handler map + vector, mirroring the paper's Fig. 7."""
        lines = ["======== BEGIN HANDLER MAP ========"]
        for r in self._records:
            lines.append(f"stable_name: {r.stable_name}")
            lines.append(f"handler: {r.fn!r}")
        lines.append("======== END HANDLER MAP ==========")
        lines.append("====== BEGIN HANDLER VECTOR =======")
        for i, r in enumerate(self._records):
            lines.append(f"index: {i}, handler: {r.fn.__qualname__}")
        lines.append("====== END HANDLER VECTOR =========")
        return "\n".join(lines)


def _derive_stable_name(fn: Callable, specs: tuple | None, explicit: str | None) -> str:
    if explicit is not None:
        base = explicit
    else:
        qualname = getattr(fn, "__qualname__", None)
        module = getattr(fn, "__module__", None)
        if qualname is None or module is None:
            raise UnstableNameError(
                f"cannot derive a stable name for {fn!r}; pass name= explicitly"
            )
        if "<lambda>" in qualname or "<locals>" in qualname:
            raise UnstableNameError(
                f"{module}:{qualname} is not stable across processes (the "
                "paper's lambda caveat, §5.1): lambdas and closures get "
                "compiler/interpreter-internal names.  Register with an "
                "explicit name= (the l2f route)."
            )
        base = f"{module}:{qualname}"
    if specs is None:
        return base + "#dyn"
    digest = hashlib.sha256(canonical_spec_string(specs).encode()).hexdigest()[:12]
    return f"{base}#{digest}"


def _validate_registration(fn, arg_specs, result_specs, name) -> None:
    """Call-time twin of the static checks in ``repro.analysis.hamlint``:
    everything hamlint rejects statically that is *cheap* to verify here is
    rejected at the registration site too, so the dynamic path and the
    static pass can never disagree silently.

    Two checks: (1) a static ``arg_specs`` tuple must match the function's
    positional arity (skipped for ``*args`` signatures and C callables
    without introspectable signatures); (2) static specs must actually be
    wire-plan-compilable — a bad leaf fails HERE, naming the handler, not
    at ``init()`` in a different stack frame.
    """
    import inspect

    if arg_specs is not None:
        try:
            sig = inspect.signature(fn)
        except (TypeError, ValueError):
            sig = None
        if sig is not None:
            params = list(sig.parameters.values())
            has_varargs = any(
                p.kind is inspect.Parameter.VAR_POSITIONAL for p in params
            )
            positional = [
                p for p in params
                if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                              inspect.Parameter.POSITIONAL_OR_KEYWORD)
            ]
            if not has_varargs and len(arg_specs) != len(positional):
                raise RegistryError(
                    f"handler {name or getattr(fn, '__qualname__', fn)!r}: "
                    f"arg_specs declares {len(arg_specs)} leaves but the "
                    f"function takes {len(positional)} positional "
                    "parameters — the wire payload and the call would "
                    "disagree (hamlint: spec-coherence)"
                )
    for label, specs in (("arg_specs", arg_specs),
                         ("result_specs", result_specs)):
        if specs is None:
            continue
        try:
            compile_plan(specs)
        except Exception as e:
            raise RegistryError(
                f"handler {name or getattr(fn, '__qualname__', fn)!r}: "
                f"{label} is not wire-plan compilable: {e}"
            ) from e


class HandlerRegistry:
    """Collects handler registrations, then seals into a :class:`HandlerTable`.

    ``construct on first use``: the default process-global registry is created
    lazily by :func:`default_registry`, mirroring the paper's idiom for
    guaranteeing static-initialisation order.
    """

    def __init__(self):
        self._pending: dict[str, HandlerRecord] = {}
        self._table: HandlerTable | None = None
        self._lock = threading.Lock()
        self._allow_late = False  # elastic mode: permit re-init after seal

    # -- registration (static-init phase) ---------------------------------

    def register(
        self,
        fn: Callable,
        *,
        arg_specs: tuple | None = None,
        result_specs: tuple | None = None,
        name: str | None = None,
        doc: str = "",
        read_only: bool = False,
        mutates: bool = False,
    ) -> HandlerRecord:
        _validate_registration(fn, arg_specs, result_specs, name)
        if read_only and mutates:
            raise RegistryError(
                f"handler {name or getattr(fn, '__qualname__', fn)!r}: "
                "read_only=True and mutates=True are mutually exclusive — "
                "a handler either never writes through its buffers or "
                "declares that it does"
            )
        stable = _derive_stable_name(fn, arg_specs, name)
        record = HandlerRecord(stable, fn, arg_specs, result_specs, doc,
                               read_only, mutates)
        with self._lock:
            if self._table is not None and not self._allow_late:
                raise RegistrySealedError(
                    f"registry already initialised; cannot register {stable!r}. "
                    "Re-init explicitly for elastic membership changes."
                )
            existing = self._pending.get(stable)
            if existing is not None and existing.fn is not fn:
                raise RegistryError(
                    f"stable-name collision: {stable!r} registered twice with "
                    "different functions"
                )
            self._pending[stable] = record
            if self._table is not None:
                # late registration in elastic mode invalidates the seal
                self._table = None
        return record

    def handler(
        self,
        fn: Callable | None = None,
        *,
        args: Sequence[Any] | None = None,
        arg_specs: tuple | None = None,
        result_specs: tuple | None = None,
        name: str | None = None,
        read_only: bool = False,
        mutates: bool = False,
    ):
        """Decorator form.  ``args=`` gives example values to derive a static
        spec from (the ``Pars...`` of the closure template); ``arg_specs=``
        passes specs directly; neither => dynamic payload.  ``read_only=True``
        declares the handler never writes through a ``buffer_ptr`` argument
        (see :class:`HandlerRecord`) — it is what allows a replicated data
        plane to serve the call from any replica.  ``mutates=True`` declares
        the opposite intent: the handler writes buffers in place, the call is
        pinned to the primary, and the data plane commits the write (dirty
        epoch bump + replica invalidation) when it returns."""

        def wrap(f: Callable) -> Callable:
            specs = arg_specs
            if specs is None and args is not None:
                specs = tuple(spec_of(a) for a in args)
            self.register(f, arg_specs=specs, result_specs=result_specs,
                          name=name, read_only=read_only, mutates=mutates)
            return f

        if fn is not None:
            return wrap(fn)
        return wrap

    # -- init (explicit, like the paper's init() from main()) --------------

    def init(self, *, allow_late_registration: bool = False) -> HandlerTable:
        with self._lock:
            self._allow_late = allow_late_registration
            self._table = HandlerTable(list(self._pending.values()))
            return self._table

    def reinit(self) -> HandlerTable:
        """Re-seal after late registrations — the elastic-membership path.

        A process that registered handlers after ``init()`` (in
        ``allow_late_registration`` mode) re-derives the key table here,
        keeping its late-registration setting; every other member derives
        the identical table from the same source, no negotiation (paper
        §5.2).  Whether members actually agree is checked separately:
        ``verify_peer_digest`` compares table digests, and
        ``ClusterPool.add_node`` runs that check on every elastic join.
        """
        return self.init(allow_late_registration=self._allow_late)

    @property
    def table(self) -> HandlerTable:
        if self._table is None:
            raise RegistryError(
                "registry not initialised; call init() before exchanging "
                "active messages (paper §5.2, step two)"
            )
        return self._table

    @property
    def initialised(self) -> bool:
        return self._table is not None

    def pending_names(self) -> list[str]:
        with self._lock:
            return sorted(self._pending)

    def pending_records(self) -> list[HandlerRecord]:
        with self._lock:
            return [self._pending[k] for k in sorted(self._pending)]

    def fork(self) -> "HandlerRegistry":
        """Copy of the pending set (for tests / simulated processes)."""
        clone = HandlerRegistry()
        with self._lock:
            clone._pending = dict(self._pending)
        return clone


# -- process-global default registry ("construct on first use") -----------

_default: HandlerRegistry | None = None
_default_lock = threading.Lock()


def default_registry() -> HandlerRegistry:
    global _default
    if _default is None:
        with _default_lock:
            if _default is None:
                _default = HandlerRegistry()
    return _default


def handler(fn=None, **kw):
    """``@ham.handler`` — register into the process-global registry."""
    return default_registry().handler(fn, **kw)


def init(**kw) -> HandlerTable:
    """``ham.init()`` — seal the process-global registry (call from main)."""
    return default_registry().init(**kw)


def verify_peer_digest(local: HandlerTable, peer_digest: bytes) -> None:
    """32-byte handshake that *verifies* the paper's same-source assumption."""
    if local.digest != peer_digest:
        from repro.core.errors import KeyMapMismatchError

        raise KeyMapMismatchError(
            "peer handler-table digest differs from local digest; processes "
            "were built from different handler sets (the heterogeneous "
            "same-source assumption is violated)"
        )
