"""Futures for asynchronous offloads (paper §2: ``offload::async`` returns a
``future<double>``; §4.3: ``offload_result_msg`` routes the result back).

A :class:`FutureTable` correlates reply messages with outstanding futures via
the 64-bit ``msg_id`` in the frame header.  Each future remembers its
``msg_id`` so higher layers (the cluster scheduler) can cancel/fail a
specific in-flight call through the table — popping the entry there means a
stale reply from a dead-then-restarted worker is dropped instead of
resurrecting an already-failed future.

:func:`as_completed` turns a set of futures into a completion-order stream —
the pipelining primitive: callers harvest results as replies arrive instead
of serialising on submission order.
"""

from __future__ import annotations

import itertools
import queue as _queue
import threading
import time
from typing import Any, Callable, Iterable, Iterator

from repro.core.errors import OffloadError, RemoteExecutionError

_UNSET = object()  # "use the default" sentinel (None must keep meaning forever)


class Future:
    """Single-assignment result container with blocking ``get``.

    Two wait surfaces: :meth:`get` (``timeout=None`` waits forever — the
    paper's blocking semantics, raises ``TimeoutError`` on expiry) and
    :meth:`result`, which defaults to :attr:`default_timeout` and raises an
    :class:`OffloadError` *diagnosis* instead of blocking forever on a lost
    reply — the failure-model surface (docs/failure-model.md).
    """

    __slots__ = ("_event", "_result", "_error", "_callbacks", "_lock", "msg_id")

    #: class-wide default for :meth:`result` (seconds; None = wait forever).
    #: Assign ``Future.default_timeout = ...`` to retune a whole process.
    default_timeout: float | None = 60.0

    def __init__(self):
        self._event = threading.Event()
        self._result: Any = None
        self._error: BaseException | None = None
        self._callbacks: list[Callable[["Future"], None]] = []
        self._lock = threading.Lock()
        #: reply-correlation id in the owning FutureTable (0 = untracked)
        self.msg_id: int = 0

    def done(self) -> bool:
        return self._event.is_set()

    def set_result(self, value: Any) -> None:
        with self._lock:
            self._result = value
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            cb(self)

    def set_exception(self, exc: BaseException) -> None:
        with self._lock:
            self._error = exc
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            cb(self)

    def add_done_callback(self, cb: Callable[["Future"], None]) -> None:
        run_now = False
        with self._lock:
            if self._event.is_set():
                run_now = True
            else:
                self._callbacks.append(cb)
        if run_now:
            cb(self)

    def get(self, timeout: float | None = None) -> Any:
        """Block until the result message arrives (``result.get()``)."""
        if not self._event.wait(timeout):
            raise TimeoutError("future did not complete in time")
        if self._error is not None:
            raise self._error
        return self._result

    def result(self, timeout=_UNSET):
        """Like :meth:`get`, but bounded by default: waits at most
        ``timeout`` (omitted => :attr:`default_timeout`; ``None`` = forever)
        and expiry raises an :class:`OffloadError` diagnosis — a lost reply
        surfaces as an explained failure, not an eternal block.  The future
        stays pending: a late reply can still resolve it."""
        if timeout is _UNSET:
            timeout = self.default_timeout
        if self._event.wait(timeout):
            if self._error is not None:
                raise self._error
            return self._result
        raise OffloadError(
            f"no reply within {timeout}s (msg_id {self.msg_id}): the call "
            "may still be executing, its reply may be lost, or the worker "
            "may be partitioned.  Submit with a deadline/retries through "
            "the scheduler for at-least-a-diagnosis semantics — delivery "
            "guarantees per path are in docs/failure-model.md"
        )

    def exception(self) -> BaseException | None:
        """The stored error of a completed future (None while pending/ok)."""
        return self._error


def as_completed(
    futures: Iterable[Future], timeout: float | None = None
) -> Iterator[Future]:
    """Yield ``futures`` in *completion* order — the pipelining iterator.

    Like ``concurrent.futures.as_completed``: each yielded future is done
    (its ``get(0)`` returns immediately), so a caller draining a fan-out of
    offloads overlaps its own post-processing with the still-in-flight
    remainder.  ``timeout`` bounds the total wait across all futures;
    expiry raises :class:`TimeoutError` with the undone count.

    Requires someone else (an event-loop thread) to resolve the futures —
    do not use from an ``inline`` host, which pumps its own endpoint.
    """
    futs = list(futures)
    done_q: _queue.SimpleQueue[Future] = _queue.SimpleQueue()
    for f in futs:
        f.add_done_callback(done_q.put)  # runs immediately if already done
    deadline = None if timeout is None else time.monotonic() + timeout
    for i in range(len(futs)):
        remaining = None if deadline is None else deadline - time.monotonic()
        if remaining is not None and remaining <= 0:
            raise TimeoutError(
                f"{len(futs) - i} of {len(futs)} futures undone at timeout"
            )
        try:
            yield done_q.get(timeout=remaining)
        except _queue.Empty:
            raise TimeoutError(
                f"{len(futs) - i} of {len(futs)} futures undone at timeout"
            ) from None


def gather(futures: Iterable[Future], timeout: float | None = None) -> list:
    """Results of ``futures`` in *submission* order, waiting in completion
    order — one shared deadline instead of per-future timeouts, and
    **fail-fast**: the first future to complete with an error raises it
    immediately (a hung sibling must not bury a real remote error under a
    generic deadline TimeoutError)."""
    futs = list(futures)
    for f in as_completed(futs, timeout):
        exc = f.exception()
        if exc is not None:
            raise exc
    return [f.get(0) for f in futs]


class FutureTable:
    """msg_id -> Future correlation for reply routing."""

    def __init__(self):
        self._counter = itertools.count(1)
        self._lock = threading.Lock()
        self._pending: dict[int, Future] = {}

    def create(self) -> tuple[int, Future]:
        fut = Future()
        msg_id = next(self._counter)
        fut.msg_id = msg_id
        with self._lock:
            self._pending[msg_id] = fut
        return msg_id, fut

    def resolve(self, msg_id: int, value: Any) -> bool:
        with self._lock:
            fut = self._pending.pop(msg_id, None)
        if fut is None:
            return False
        fut.set_result(value)
        return True

    def reject(self, msg_id: int, message: str, remote_traceback: str = "") -> bool:
        with self._lock:
            fut = self._pending.pop(msg_id, None)
        if fut is None:
            return False
        fut.set_exception(RemoteExecutionError(message, remote_traceback))
        return True

    def discard(self, msg_id: int) -> bool:
        """Drop a pending entry WITHOUT completing the future — for a
        created-but-never-sent msg_id (e.g. a scheduler that reserved a
        future, then lost its target to a membership fence before sending).
        A later reply for the id is ignored; safe if already completed."""
        with self._lock:
            return self._pending.pop(msg_id, None) is not None

    def fail_all(self, exc: BaseException) -> int:
        """Reject every outstanding future (node-death path)."""
        with self._lock:
            pending, self._pending = self._pending, {}
        for fut in pending.values():
            fut.set_exception(exc)
        return len(pending)

    def outstanding(self) -> int:
        with self._lock:
            return len(self._pending)
