"""Futures for asynchronous offloads (paper §2: ``offload::async`` returns a
``future<double>``; §4.3: ``offload_result_msg`` routes the result back).

A :class:`FutureTable` correlates reply messages with outstanding futures via
the 64-bit ``msg_id`` in the frame header.
"""

from __future__ import annotations

import itertools
import threading
from typing import Any, Callable

from repro.core.errors import RemoteExecutionError


class Future:
    """Single-assignment result container with blocking ``get``."""

    __slots__ = ("_event", "_result", "_error", "_callbacks", "_lock")

    def __init__(self):
        self._event = threading.Event()
        self._result: Any = None
        self._error: BaseException | None = None
        self._callbacks: list[Callable[["Future"], None]] = []
        self._lock = threading.Lock()

    def done(self) -> bool:
        return self._event.is_set()

    def set_result(self, value: Any) -> None:
        with self._lock:
            self._result = value
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            cb(self)

    def set_exception(self, exc: BaseException) -> None:
        with self._lock:
            self._error = exc
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            cb(self)

    def add_done_callback(self, cb: Callable[["Future"], None]) -> None:
        run_now = False
        with self._lock:
            if self._event.is_set():
                run_now = True
            else:
                self._callbacks.append(cb)
        if run_now:
            cb(self)

    def get(self, timeout: float | None = None) -> Any:
        """Block until the result message arrives (``result.get()``)."""
        if not self._event.wait(timeout):
            raise TimeoutError("future did not complete in time")
        if self._error is not None:
            raise self._error
        return self._result


class FutureTable:
    """msg_id -> Future correlation for reply routing."""

    def __init__(self):
        self._counter = itertools.count(1)
        self._lock = threading.Lock()
        self._pending: dict[int, Future] = {}

    def create(self) -> tuple[int, Future]:
        fut = Future()
        msg_id = next(self._counter)
        with self._lock:
            self._pending[msg_id] = fut
        return msg_id, fut

    def resolve(self, msg_id: int, value: Any) -> bool:
        with self._lock:
            fut = self._pending.pop(msg_id, None)
        if fut is None:
            return False
        fut.set_result(value)
        return True

    def reject(self, msg_id: int, message: str, remote_traceback: str = "") -> bool:
        with self._lock:
            fut = self._pending.pop(msg_id, None)
        if fut is None:
            return False
        fut.set_exception(RemoteExecutionError(message, remote_traceback))
        return True

    def fail_all(self, exc: BaseException) -> int:
        """Reject every outstanding future (node-death path)."""
        with self._lock:
            pending, self._pending = self._pending, {}
        for fut in pending.values():
            fut.set_exception(exc)
        return len(pending)

    def outstanding(self) -> int:
        with self._lock:
            return len(self._pending)
