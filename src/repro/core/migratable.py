"""Migratable values: per-type serialisation between address spaces.

Paper mapping (§4.3, §5.1):

* ``migratable<T>``      -> the codec registry in this module.  A type that
  cannot be bitwise-copied provides an *encode* hook (converting constructor)
  and a *decode* hook (conversion operator).
* ``is_bitwise_copyable`` -> :func:`is_bitwise_migratable`; violations raise
  :class:`NotBitwiseMigratableError` at closure-construction time, the
  Python analogue of the paper's compile-time trap.
* The tuple ``std::tuple<migratable<Pars>...>`` storing a closure's arguments
  corresponds to the **static pack** path: the receiving side knows the
  argument specs *from the handler's registration* (the message type), so the
  payload is a raw concatenation of fixed-size leaf bytes — no per-message
  descriptors, which is what makes the fast path fast.
* A **dynamic (self-describing) pack** path exists for `put`/`get` of
  arbitrary pytrees, analogous to serialising a non-trivial type through a
  ``migratable`` specialisation.

Endianness is pinned little-endian; implementation-defined-width Python ints
are pinned to int64 (the paper's §6 advice: avoid ``int``/``long double``,
use fixed-size types).
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Any, Callable

import numpy as np

from repro.core.errors import (
    MigratableError,
    NotBitwiseMigratableError,
    SpecMismatchError,
)

# --------------------------------------------------------------------------
# Argument specs (the "Pars..." of the closure template)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Fixed-shape, fixed-dtype array leaf — bitwise migratable."""

    shape: tuple
    dtype: str  # canonical numpy dtype string, e.g. "float32"

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n * np.dtype(self.dtype).itemsize

    def canonical(self) -> str:
        return f"array[{self.dtype};{','.join(str(int(d)) for d in self.shape)}]"


@dataclasses.dataclass(frozen=True)
class ScalarSpec:
    """Fixed-width scalar leaf.  kind in {'i8','f8','b1'} (int64/float64/bool)."""

    kind: str

    _SIZES = {"i8": 8, "f8": 8, "b1": 1}

    @property
    def nbytes(self) -> int:
        return self._SIZES[self.kind]

    def canonical(self) -> str:
        return f"scalar[{self.kind}]"


@dataclasses.dataclass(frozen=True)
class OpaqueSpec:
    """Custom registered type with a fixed-size wire format."""

    type_name: str
    nbytes_fixed: int

    @property
    def nbytes(self) -> int:
        return self.nbytes_fixed

    def canonical(self) -> str:
        return f"opaque[{self.type_name};{self.nbytes_fixed}]"


Spec = Any  # ArraySpec | ScalarSpec | OpaqueSpec


def canonical_spec_string(specs) -> str:
    """Canonical textual form of an argument spec tuple.

    Feeds the registry's stable-name digest — the analogue of the signature
    part of the C++ mangled name, so two handlers with the same qualname but
    different argument specs get different identities.
    """
    return "(" + ",".join(s.canonical() for s in specs) + ")"


# --------------------------------------------------------------------------
# Custom codec registry (migratable<T> specialisations)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Codec:
    type_name: str
    py_type: type
    encode: Callable[[Any], bytes]          # converting constructor
    decode: Callable[[bytes], Any]          # conversion operator
    nbytes_fixed: int | None                # None => dynamic size only
    locality: Callable[[Any], int | None] | None = None  # owning node hint
    #: bytes the value stands for AT its owning node (a buffer_ptr's remote
    #: buffer size) — weights locality votes; None => weight 1
    locality_nbytes: Callable[[Any], int] | None = None


_CODECS_BY_TYPE: dict[type, _Codec] = {}
_CODECS_BY_NAME: dict[str, _Codec] = {}


def register_migratable(
    py_type: type,
    encode: Callable[[Any], bytes],
    decode: Callable[[bytes], Any],
    *,
    type_name: str | None = None,
    nbytes_fixed: int | None = None,
    locality: Callable[[Any], int | None] | None = None,
    locality_nbytes: Callable[[Any], int] | None = None,
) -> None:
    """Register a ``migratable`` specialisation for ``py_type``.

    ``nbytes_fixed`` enables use in *static* handler specs (fixed wire size);
    without it the type is only usable on the dynamic path.

    ``encode`` must be deterministic (same value -> same bytes, in particular
    the same length): the dynamic pack path measures frames with one encode
    call and packs with another, so a length that varies between calls would
    corrupt the frame.

    ``locality`` optionally maps a value to the node that *owns* it (e.g. a
    ``buffer_ptr``'s address space).  Locality-aware schedulers use it to
    route a call to the data instead of moving the data to the call — the
    data-centric dispatch of Active Access.  ``locality_nbytes`` sizes that
    vote: the bytes the value stands for at its owner (a buffer_ptr's
    remote buffer size), so a node holding 100 MB outweighs one holding
    three 8-byte scalars regardless of pointer count.
    """
    name = type_name or f"{py_type.__module__}:{py_type.__qualname__}"
    codec = _Codec(name, py_type, encode, decode, nbytes_fixed, locality,
                   locality_nbytes)
    _CODECS_BY_TYPE[py_type] = codec
    _CODECS_BY_NAME[name] = codec


def codec_for(value: Any) -> _Codec | None:
    return _CODECS_BY_TYPE.get(type(value))


def locality_of(value: Any) -> int | None:
    """Owning node of ``value`` per its codec's locality hook, else None."""
    codec = _CODECS_BY_TYPE.get(type(value))
    if codec is None or codec.locality is None:
        return None
    return codec.locality(value)


#: container-nesting bound shared by every submit-path argument walk
#: (``scan_locality`` here, ``BufferDirectory.resolve_args`` in the
#: dataplane).  The two walks MUST agree: a pointer deep enough to vote
#: must also be deep enough to be rewritten, or locality routing could
#: ship a frame whose stale hint fails the holder's own-address-space
#: dereference check.
MAX_SCAN_DEPTH = 32


def scan_locality(values, max_items: int = 64, resolver=None) -> dict[int, int]:
    """Byte-weighted locality votes across a shallow pytree of arguments.

    Returns ``{node: weight}`` over every leaf with a registered locality
    hook, walking at most ``max_items`` leaves (schedulers run this per
    submit — it must stay O(small)).  Containers are descended one level at
    a time, at most ``MAX_SCAN_DEPTH`` levels deep (the same bound the
    directory's ``resolve_args`` rewrite walk applies, so a vote always
    implies a rewritable pointer); everything else is a leaf.

    A leaf's vote weighs its ``locality_nbytes`` (the data it stands for at
    its owner — a buffer_ptr's remote buffer size), clamped to >= 1 so a
    value of unknown size still counts.  Routing to the most-bytes node is
    what makes "move the compute, not the data" true when buffer sizes are
    skewed: under the old count-per-pointer scheme a node owning one 8-byte
    scalar could outvote a node owning a 100 MB tensor.

    ``resolver`` widens a leaf's vote beyond the codec's single-node hint:
    called per leaf, it may return ``{node: weight}`` (used as-is) or None
    (fall through to the codec).  A cluster's ``BufferDirectory`` supplies
    one so a replicated buffer votes for EVERY live holder — any copy can
    serve a read, which is what makes locality routing survive the primary.
    """
    votes: dict[int, int] = {}
    top = list(values) if isinstance(values, (list, tuple)) else [values]
    stack = [(v, 0) for v in top]
    seen = 0
    while stack and seen < max_items:
        v, depth = stack.pop()
        seen += 1
        if isinstance(v, (list, tuple)):
            if depth < MAX_SCAN_DEPTH:
                stack.extend((i, depth + 1) for i in v)
            continue
        if isinstance(v, dict):
            if depth < MAX_SCAN_DEPTH:
                stack.extend((i, depth + 1) for i in v.values())
            continue
        if resolver is not None:
            resolved = resolver(v)
            if resolved is not None:
                for node, weight in resolved.items():
                    votes[node] = votes.get(node, 0) + max(1, int(weight))
                continue
        codec = _CODECS_BY_TYPE.get(type(v))
        if codec is None or codec.locality is None:
            continue
        node = codec.locality(v)
        if node is None:
            continue
        weight = 1
        if codec.locality_nbytes is not None:
            weight = max(1, int(codec.locality_nbytes(v)))
        votes[node] = votes.get(node, 0) + weight
    return votes


def is_bitwise_migratable(value: Any) -> bool:
    """True if a value needs no codec: fixed-size array/scalar leaves."""
    if isinstance(value, (bool, int, float, np.bool_, np.integer, np.floating)):
        return True
    if isinstance(value, np.ndarray):
        return value.dtype.kind in "biufc"
    # jax.Array quacks like ndarray for our purposes
    if hasattr(value, "__array__") and hasattr(value, "dtype") and hasattr(value, "shape"):
        return True
    return False


# --------------------------------------------------------------------------
# spec_of: value -> Spec
# --------------------------------------------------------------------------


def spec_of(value: Any) -> Spec:
    if isinstance(value, (bool, np.bool_)):
        return ScalarSpec("b1")
    if isinstance(value, (int, np.integer)):
        return ScalarSpec("i8")
    if isinstance(value, (float, np.floating)):
        return ScalarSpec("f8")
    codec = codec_for(value)
    if codec is not None:
        if codec.nbytes_fixed is None:
            raise MigratableError(
                f"type {codec.type_name} has a dynamic-size codec and cannot "
                "appear in a static handler spec"
            )
        return OpaqueSpec(codec.type_name, codec.nbytes_fixed)
    if hasattr(value, "shape") and hasattr(value, "dtype"):
        arr = np.asarray(value)
        if arr.dtype.kind not in "biufc":
            raise NotBitwiseMigratableError(
                f"array dtype {arr.dtype} is not bitwise migratable"
            )
        return ArraySpec(tuple(int(d) for d in arr.shape), str(arr.dtype))
    raise NotBitwiseMigratableError(
        f"type {type(value).__qualname__} is neither bitwise migratable nor "
        "has a registered migratable codec; register one with "
        "register_migratable() (the migratable<T> specialisation)"
    )


def check_against_spec(value: Any, spec: Spec) -> None:
    got = spec_of(value)
    if got != spec:
        raise SpecMismatchError(f"argument spec mismatch: expected {spec}, got {got}")


# --------------------------------------------------------------------------
# STATIC pack/unpack: raw leaf concatenation, spec known to both sides
# --------------------------------------------------------------------------


# precompiled per-kind structs: (Struct, python-side coercion).  struct's
# internal format cache makes repeated struct.pack("<q", ...) merely cheap;
# hoisting the compiled objects makes the per-leaf cost one method call.
_SCALAR_STRUCTS = {
    "i8": (struct.Struct("<q"), int),
    "f8": (struct.Struct("<d"), float),
    "b1": (struct.Struct("<?"), bool),
}


def _scalar_to_bytes(value: Any, kind: str) -> bytes:
    try:
        st, conv = _SCALAR_STRUCTS[kind]
    except KeyError:
        raise MigratableError(f"unknown scalar kind {kind}") from None
    return st.pack(conv(value))


def _scalar_from_bytes(buf: memoryview, kind: str) -> Any:
    try:
        st, _ = _SCALAR_STRUCTS[kind]
    except KeyError:
        raise MigratableError(f"unknown scalar kind {kind}") from None
    return st.unpack(buf[: st.size])[0]


def static_payload_nbytes(specs) -> int:
    return sum(s.nbytes for s in specs)


def pack_static(args, specs, out=None):
    """Pack ``args`` against ``specs`` into a contiguous buffer.

    This is the paper's bitwise-copy fast path: no tags, no shapes, no dtype
    strings on the wire — the receiver reconstructs purely from the handler's
    registered spec.  ``out`` may be a presized bytearray or writable
    memoryview (frames pack payloads in place).
    """
    if len(args) != len(specs):
        raise SpecMismatchError(f"expected {len(specs)} args, got {len(args)}")
    buf = out if out is not None else bytearray(static_payload_nbytes(specs))
    off = 0
    for value, spec in zip(args, specs):
        if isinstance(spec, ScalarSpec):
            b = _scalar_to_bytes(value, spec.kind)
            buf[off : off + len(b)] = b
            off += spec.nbytes
        elif isinstance(spec, ArraySpec):
            arr = np.asarray(value)
            if tuple(arr.shape) != spec.shape or str(arr.dtype) != spec.dtype:
                raise SpecMismatchError(
                    f"array arg mismatch: expected {spec}, got "
                    f"shape={tuple(arr.shape)} dtype={arr.dtype}"
                )
            # single copy straight into the wire buffer (bitwise fast path)
            dst = np.frombuffer(buf, np.uint8, count=spec.nbytes, offset=off)
            np.copyto(dst, np.ascontiguousarray(arr).view(np.uint8).reshape(-1))
            off += spec.nbytes
        elif isinstance(spec, OpaqueSpec):
            codec = _CODECS_BY_NAME[spec.type_name]
            raw = codec.encode(value)
            if len(raw) != spec.nbytes_fixed:
                raise SpecMismatchError(
                    f"codec {spec.type_name} produced {len(raw)} bytes, "
                    f"spec says {spec.nbytes_fixed}"
                )
            buf[off : off + len(raw)] = raw
            off += spec.nbytes
        else:
            raise MigratableError(f"unknown spec {spec!r}")
    return buf  # bytearray: transports accept buffer-protocol objects


def unpack_static(payload: bytes | memoryview, specs) -> tuple:
    """Inverse of :func:`pack_static`.  Array leaves are zero-copy views."""
    view = memoryview(payload)
    args = []
    off = 0
    for spec in specs:
        if isinstance(spec, ScalarSpec):
            args.append(_scalar_from_bytes(view[off:], spec.kind))
        elif isinstance(spec, ArraySpec):
            arr = np.frombuffer(
                view[off : off + spec.nbytes], dtype=np.dtype(spec.dtype)
            ).reshape(spec.shape)
            args.append(arr)
        elif isinstance(spec, OpaqueSpec):
            codec = _CODECS_BY_NAME.get(spec.type_name)
            if codec is None:
                raise MigratableError(
                    f"no codec registered locally for {spec.type_name}; "
                    "heterogeneous processes must register the same migratable "
                    "specialisations (same-source assumption)"
                )
            args.append(codec.decode(bytes(view[off : off + spec.nbytes])))
        else:
            raise MigratableError(f"unknown spec {spec!r}")
        off += spec.nbytes
    return tuple(args)


# --------------------------------------------------------------------------
# DYNAMIC pack/unpack: self-describing pytree TLV
# --------------------------------------------------------------------------

_T_NONE = 0
_T_BOOL = 1
_T_INT = 2
_T_FLOAT = 3
_T_BYTES = 4
_T_STR = 5
_T_NDARRAY = 6
_T_LIST = 7
_T_TUPLE = 8
_T_DICT = 9
_T_CUSTOM = 10


def _as_flat_view(value) -> memoryview:
    """1-D uint8 memoryview of any bytes-like, without copying when possible."""
    mv = value if isinstance(value, memoryview) else memoryview(value)
    if mv.format != "B" or mv.ndim != 1:
        try:
            mv = mv.cast("B")
        except TypeError:  # non-contiguous exotic view: flatten via a copy
            mv = memoryview(bytes(mv))
    return mv


def dynamic_nbytes(value: Any) -> int:
    """Exact packed size of ``value`` under the dynamic encoding.

    A cheap measuring pre-pass mirroring :func:`pack_dynamic_into`'s dispatch
    order, so frames can be allocated at their final size up front — no
    bytearray growth reallocs (which cost an extra full copy or two on
    multi-megabyte put/get payloads).
    """
    if value is None:
        return 1
    if isinstance(value, (bool, np.bool_)):
        return 2
    if isinstance(value, (int, np.integer)):
        return 9
    if isinstance(value, (float, np.floating)):
        return 9
    if isinstance(value, (bytes, bytearray, memoryview)):
        return 9 + _as_flat_view(value).nbytes
    if isinstance(value, str):
        return 9 + len(value.encode("utf-8"))
    codec = codec_for(value)
    if codec is not None:
        name = codec.type_name.encode("utf-8")
        return 11 + len(name) + len(codec.encode(value))
    if hasattr(value, "shape") and hasattr(value, "dtype"):
        arr = np.asarray(value)
        return (
            3
            + len(arr.dtype.str)
            + 8 * arr.ndim
            + arr.size * arr.dtype.itemsize
        )
    if isinstance(value, (list, tuple)):
        return 9 + sum(dynamic_nbytes(v) for v in value)
    if isinstance(value, dict):
        n = 9
        for k, v in value.items():
            if not isinstance(k, str):
                raise MigratableError("dynamic dict keys must be str")
            n += dynamic_nbytes(k) + dynamic_nbytes(v)
        return n
    raise NotBitwiseMigratableError(
        f"type {type(value).__qualname__} has no migratable codec"
    )


def pack_dynamic_into(buf: bytearray, off: int, value: Any) -> int:
    """Pack ``value`` into presized ``buf`` at ``off``; returns the end offset.

    ``buf`` must have at least :func:`dynamic_nbytes` bytes of room after
    ``off`` — callers allocate the frame once (header + payload) and pack in
    place, which is the zero-intermediate-copy fast path the transports
    build on.
    """
    if value is None:
        buf[off] = _T_NONE
        return off + 1
    if isinstance(value, (bool, np.bool_)):
        buf[off] = _T_BOOL
        buf[off + 1] = 1 if value else 0
        return off + 2
    if isinstance(value, (int, np.integer)):
        buf[off] = _T_INT
        struct.pack_into("<q", buf, off + 1, int(value))
        return off + 9
    if isinstance(value, (float, np.floating)):
        buf[off] = _T_FLOAT
        struct.pack_into("<d", buf, off + 1, float(value))
        return off + 9
    if isinstance(value, (bytes, bytearray, memoryview)):
        mv = _as_flat_view(value)
        n = mv.nbytes
        buf[off] = _T_BYTES
        struct.pack_into("<Q", buf, off + 1, n)
        off += 9
        buf[off : off + n] = mv
        return off + n
    if isinstance(value, str):
        raw = value.encode("utf-8")
        buf[off] = _T_STR
        struct.pack_into("<Q", buf, off + 1, len(raw))
        off += 9
        buf[off : off + len(raw)] = raw
        return off + len(raw)
    codec = codec_for(value)
    if codec is not None:
        name = codec.type_name.encode("utf-8")
        raw = codec.encode(value)
        buf[off] = _T_CUSTOM
        struct.pack_into("<H", buf, off + 1, len(name))
        off += 3
        buf[off : off + len(name)] = name
        off += len(name)
        struct.pack_into("<Q", buf, off, len(raw))
        off += 8
        buf[off : off + len(raw)] = raw
        return off + len(raw)
    if hasattr(value, "shape") and hasattr(value, "dtype"):
        arr = np.asarray(value)
        if arr.dtype.kind not in "biufcV":
            raise NotBitwiseMigratableError(f"cannot migrate dtype {arr.dtype}")
        dt = arr.dtype.str.encode("ascii")  # includes endianness, e.g. '<f4'
        buf[off] = _T_NDARRAY
        buf[off + 1] = len(dt)
        off += 2
        buf[off : off + len(dt)] = dt
        off += len(dt)
        buf[off] = arr.ndim
        off += 1
        for d in arr.shape:
            struct.pack_into("<Q", buf, off, d)
            off += 8
        nb = arr.size * arr.dtype.itemsize
        if nb:
            # bulk leaf: single copy straight into the frame (no tobytes temp)
            dst = np.frombuffer(buf, np.uint8, count=nb, offset=off)
            np.copyto(dst, np.ascontiguousarray(arr).reshape(-1).view(np.uint8))
        return off + nb
    if isinstance(value, (list, tuple)):
        buf[off] = _T_LIST if isinstance(value, list) else _T_TUPLE
        struct.pack_into("<Q", buf, off + 1, len(value))
        off += 9
        for item in value:
            off = pack_dynamic_into(buf, off, item)
        return off
    if isinstance(value, dict):
        buf[off] = _T_DICT
        struct.pack_into("<Q", buf, off + 1, len(value))
        off += 9
        for k, v in value.items():
            if not isinstance(k, str):
                raise MigratableError("dynamic dict keys must be str")
            off = pack_dynamic_into(buf, off, k)
            off = pack_dynamic_into(buf, off, v)
        return off
    raise NotBitwiseMigratableError(
        f"type {type(value).__qualname__} has no migratable codec"
    )


def pack_dynamic(value: Any) -> bytes:
    """Self-describing encoding of a pytree of migratable leaves."""
    out = bytearray(dynamic_nbytes(value))
    pack_dynamic_into(out, 0, value)
    return bytes(out)


def _unpack_from(view: memoryview, off: int):
    tag = view[off]
    off += 1
    if tag == _T_NONE:
        return None, off
    if tag == _T_BOOL:
        return bool(view[off]), off + 1
    if tag == _T_INT:
        return struct.unpack_from("<q", view, off)[0], off + 8
    if tag == _T_FLOAT:
        return struct.unpack_from("<d", view, off)[0], off + 8
    if tag == _T_BYTES:
        (n,) = struct.unpack_from("<Q", view, off)
        off += 8
        return bytes(view[off : off + n]), off + n
    if tag == _T_STR:
        (n,) = struct.unpack_from("<Q", view, off)
        off += 8
        return bytes(view[off : off + n]).decode("utf-8"), off + n
    if tag == _T_CUSTOM:
        (nlen,) = struct.unpack_from("<H", view, off)
        off += 2
        name = bytes(view[off : off + nlen]).decode("utf-8")
        off += nlen
        (n,) = struct.unpack_from("<Q", view, off)
        off += 8
        codec = _CODECS_BY_NAME.get(name)
        if codec is None:
            raise MigratableError(f"no codec registered locally for {name}")
        return codec.decode(bytes(view[off : off + n])), off + n
    if tag == _T_NDARRAY:
        dtlen = view[off]
        off += 1
        dt = np.dtype(bytes(view[off : off + dtlen]).decode("ascii"))
        off += dtlen
        ndim = view[off]
        off += 1
        shape = []
        for _ in range(ndim):
            (d,) = struct.unpack_from("<Q", view, off)
            shape.append(d)
            off += 8
        nbytes = int(np.prod(shape, dtype=np.int64)) * dt.itemsize if shape else dt.itemsize
        if not shape:
            nbytes = dt.itemsize
        arr = np.frombuffer(view[off : off + nbytes], dtype=dt).reshape(shape)
        return arr, off + nbytes
    if tag in (_T_LIST, _T_TUPLE):
        (n,) = struct.unpack_from("<Q", view, off)
        off += 8
        items = []
        for _ in range(n):
            item, off = _unpack_from(view, off)
            items.append(item)
        return (items if tag == _T_LIST else tuple(items)), off
    if tag == _T_DICT:
        (n,) = struct.unpack_from("<Q", view, off)
        off += 8
        d = {}
        for _ in range(n):
            k, off = _unpack_from(view, off)
            v, off = _unpack_from(view, off)
            d[k] = v
        return d, off
    raise MigratableError(f"unknown dynamic tag {tag}")


def unpack_dynamic(payload: bytes | memoryview) -> Any:
    value, off = _unpack_from(memoryview(payload), 0)
    if off != len(payload):
        raise MigratableError(
            f"trailing bytes in dynamic payload: consumed {off} of {len(payload)}"
        )
    return value
