"""Device-side handler tables: HAM's dispatch, compiled into one executable.

This is the TPU-native centrepiece of the adaptation (DESIGN.md §2).  The
paper's receiving side is: typeless buffer -> header key -> handler-vector
index -> call.  On a TPU worker, the analogous cost structure appears when a
runtime must *select which step function to run* (prefill / decode / update /
rollback ...).  Vendor-style dispatch pays a host round-trip plus executable
swap (or worse, a re-trace) per selection.  HAMax compiles the whole handler
vector into **one** XLA executable containing a ``jax.lax.switch`` over the
branches; the key then travels as device data and dispatch costs one
integer-indexed branch on device.

Constraints (the price of a shared executable, stated up front):

* all branches must accept the same payload pytree structure/shapes/dtypes
  and produce the same result structure — the "fixed payload spec handler
  class" (validated via ``jax.eval_shape`` at build time);
* like the host registry, keys are assigned by sorting stable names, so two
  differently-compiled processes (heterogeneous binaries: different meshes,
  device kinds) agree on every device key with zero communication.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.core.errors import RegistryError, UnknownHandlerError


@dataclasses.dataclass(frozen=True)
class DeviceHandler:
    stable_name: str
    fn: Callable  # payload_pytree -> result_pytree


class DeviceHandlerTable:
    """Builds ``dispatch(key, payload)`` = ``lax.switch`` over sorted handlers."""

    def __init__(self):
        self._entries: dict[str, Callable] = {}
        self._sealed: list[DeviceHandler] | None = None

    def register(self, name: str, fn: Callable) -> Callable:
        if self._sealed is not None:
            raise RegistryError("device table already built")
        if name in self._entries and self._entries[name] is not fn:
            raise RegistryError(f"device handler name collision: {name!r}")
        self._entries[name] = fn
        return fn

    def handler(self, name: str):
        def wrap(fn: Callable) -> Callable:
            self.register(name, fn)
            return fn

        return wrap

    # -- init: sort -> keys (communication-free, as in the host registry) ---

    def seal(self) -> None:
        self._sealed = [
            DeviceHandler(n, self._entries[n]) for n in sorted(self._entries)
        ]

    @property
    def handlers(self) -> list[DeviceHandler]:
        if self._sealed is None:
            self.seal()
        return self._sealed

    def key_of(self, name: str) -> int:
        for i, h in enumerate(self.handlers):
            if h.stable_name == name:
                return i
        raise UnknownHandlerError(f"no device handler named {name!r}")

    def __len__(self) -> int:
        return len(self.handlers)

    # -- build the compiled switch table ------------------------------------

    def validate(self, payload_spec: Any) -> Any:
        """All branches must agree on the result spec for ``payload_spec``.

        Returns the common result spec.  ``jax.eval_shape`` costs no device
        memory — this is the registration-time type check, the analogue of
        the upcast being statically sound in C++.
        """
        specs = [jax.eval_shape(h.fn, payload_spec) for h in self.handlers]
        ref_tree = jax.tree_util.tree_structure(specs[0])
        ref_leaves = jax.tree_util.tree_leaves(specs[0])
        for h, s in zip(self.handlers[1:], specs[1:]):
            if jax.tree_util.tree_structure(s) != ref_tree:
                raise RegistryError(
                    f"device handler {h.stable_name!r} result tree structure "
                    f"differs from {self.handlers[0].stable_name!r}"
                )
            for a, b in zip(jax.tree_util.tree_leaves(s), ref_leaves):
                if a.shape != b.shape or a.dtype != b.dtype:
                    raise RegistryError(
                        f"device handler {h.stable_name!r} result leaf "
                        f"{a.shape}/{a.dtype} != {b.shape}/{b.dtype}"
                    )
        return specs[0]

    def build(
        self,
        payload_spec: Any,
        *,
        donate_payload: bool = False,
        jit: bool = True,
    ) -> Callable:
        """Compile ``dispatch(key, payload)``.

        ``donate_payload=True`` donates the payload buffers (serving loops
        thread a state pytree through the table; donation makes the update
        in-place on device — essential for multi-GB KV caches).
        """
        self.validate(payload_spec)
        branches = [h.fn for h in self.handlers]

        def dispatch(key, payload):
            return jax.lax.switch(key, branches, payload)

        if not jit:
            return dispatch
        donate = (1,) if donate_payload else ()
        return jax.jit(dispatch, donate_argnums=donate)

    def lower(self, payload_spec: Any, key_spec=None, **jit_kw):
        """Lower (no execution) — used by the dry-run and benchmarks."""
        import jax.numpy as jnp

        self.validate(payload_spec)
        branches = [h.fn for h in self.handlers]

        def dispatch(key, payload):
            return jax.lax.switch(key, branches, payload)

        if key_spec is None:
            key_spec = jax.ShapeDtypeStruct((), jnp.int32)
        return jax.jit(dispatch, **jit_kw).lower(key_spec, payload_spec)
