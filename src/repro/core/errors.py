"""Exception hierarchy for the HAM core.

The paper relies on C++ compile-time errors (e.g. the ``is_bitwise_copyable``
trait triggering ``static_assert``-style diagnostics).  In Python we surface
the same classes of failure as early, typed exceptions raised at registration
or closure-construction time — *before* any message crosses an address space.
"""

from __future__ import annotations


class HamError(Exception):
    """Base class for all HAM errors."""


class RegistryError(HamError):
    """Handler registry misuse (duplicate names, unsealed access, ...)."""


class RegistrySealedError(RegistryError):
    """Registration attempted after ``init()`` sealed the registry."""


class UnstableNameError(RegistryError):
    """A handler's auto-derived stable name is not stable across processes.

    The Python analogue of the paper's lambda caveat (§5.1/§6): compiler
    internal names (``_FUN`` vs ``__invoke``) differ between binaries; here,
    ``<lambda>`` / ``<locals>`` qualnames differ between refactors and
    interactive sessions.  An explicit ``name=`` resolves it (the ``l2f``
    route).
    """


class KeyMapMismatchError(HamError):
    """Two processes derived different key maps (digest handshake failed)."""


class MigratableError(HamError):
    """A value cannot be migrated between address spaces."""


class NotBitwiseMigratableError(MigratableError):
    """Type lacks a codec and is not bitwise-copyable (paper's trait trip)."""


class SpecMismatchError(MigratableError):
    """Runtime argument does not match the handler's declared static spec."""


class MessageFormatError(HamError):
    """Malformed frame: bad magic, truncated payload, unknown version."""


class UnknownHandlerError(HamError):
    """Received a key outside the local handler table."""


class CommError(HamError):
    """Transport-level failure in a communication backend."""


class NodeDownError(CommError):
    """Peer declared dead (missed heartbeats / closed transport)."""


class OffloadError(HamError):
    """Offload-layer failure (bad node id, freed buffer, ...)."""


class RemoteExecutionError(HamError):
    """The remote handler raised; carries the remote traceback string."""

    def __init__(self, message: str, remote_traceback: str = ""):
        super().__init__(message)
        self.remote_traceback = remote_traceback
