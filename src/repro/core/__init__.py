"""HAM core: the paper's contribution as a composable JAX-side module.

Public surface:

* registry: :func:`handler`, :func:`init`, :class:`HandlerRegistry`,
  :class:`HandlerTable`, :func:`verify_peer_digest`
* closures: :func:`f2f`, :func:`l2f`, :class:`Function`
* messages: :mod:`repro.core.message` framing
* migratable: :func:`register_migratable`, :func:`spec_of`, pack/unpack
* execution policies: Direct / Queue / ThreadPool
* device tables: :class:`DeviceHandlerTable` (compiled ``lax.switch`` dispatch)
* futures: :class:`Future`, :class:`FutureTable`
"""

from repro.core.closure import Function, f2f, l2f
from repro.core.device_table import DeviceHandlerTable
from repro.core.errors import (
    CommError,
    HamError,
    KeyMapMismatchError,
    MessageFormatError,
    MigratableError,
    NodeDownError,
    NotBitwiseMigratableError,
    OffloadError,
    RegistryError,
    RegistrySealedError,
    RemoteExecutionError,
    SpecMismatchError,
    UnknownHandlerError,
    UnstableNameError,
)
from repro.core.executor import DirectPolicy, ExecutionPolicy, QueuePolicy, ThreadPoolPolicy
from repro.core.future import Future, FutureTable, as_completed, gather
from repro.core.migratable import (
    ArraySpec,
    OpaqueSpec,
    ScalarSpec,
    is_bitwise_migratable,
    pack_dynamic,
    pack_static,
    register_migratable,
    spec_of,
    unpack_dynamic,
    unpack_static,
)
from repro.core.registry import (
    HandlerRecord,
    HandlerRegistry,
    HandlerTable,
    default_registry,
    handler,
    init,
    verify_peer_digest,
)
from repro.core.wireplan import WirePlan, compile_plan

__all__ = [
    "Function", "f2f", "l2f",
    "DeviceHandlerTable",
    "HamError", "RegistryError", "RegistrySealedError", "UnstableNameError",
    "KeyMapMismatchError", "MigratableError", "NotBitwiseMigratableError",
    "SpecMismatchError", "MessageFormatError", "UnknownHandlerError",
    "CommError", "NodeDownError", "OffloadError", "RemoteExecutionError",
    "ExecutionPolicy", "DirectPolicy", "QueuePolicy", "ThreadPoolPolicy",
    "Future", "FutureTable", "as_completed", "gather",
    "ArraySpec", "ScalarSpec", "OpaqueSpec",
    "spec_of", "is_bitwise_migratable", "register_migratable",
    "pack_static", "unpack_static", "pack_dynamic", "unpack_dynamic",
    "HandlerRecord", "HandlerRegistry", "HandlerTable",
    "default_registry", "handler", "init", "verify_peer_digest",
    "WirePlan", "compile_plan",
]
