"""Transferable closures — the ``function<Sig, FnPtr>`` template and ``f2f``.

Paper mapping (§5.1): the function pointer is a *template value parameter* —
part of the closure's **type**, never a data member — so no code address ever
crosses an address space.  Here, the function's identity is its **stable
name** in the handler registry; a :class:`Function` closure stores only the
key-resolvable identity plus the packed arguments.  On the receiving side the
handler (which *is* the function, registered under the same stable name)
unpacks the arguments from its statically known spec and executes.

``f2f(fn, *args)`` builds a closure from a registered handler.
``l2f(name, fn)`` registers an anonymous function under an explicit name
first (the paper's lambda workaround), then behaves like ``f2f``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.core import migratable as mig
from repro.core.errors import SpecMismatchError
from repro.core.registry import HandlerRecord, HandlerRegistry, default_registry


@dataclasses.dataclass
class Function:
    """A transferable closure: handler identity + packed arguments."""

    record: HandlerRecord
    args: tuple

    def __call__(self) -> Any:
        """Local execution (``Result operator()() const``)."""
        return self.record.fn(*self.args)

    # -- wire form ---------------------------------------------------------

    @property
    def is_static(self) -> bool:
        return self.record.is_static

    def pack_payload(self) -> bytes:
        if self.record.is_static:
            return mig.pack_static(self.args, self.record.arg_specs)
        return mig.pack_dynamic(list(self.args))

    @staticmethod
    def unpack_args(record: HandlerRecord, payload) -> tuple:
        if record.is_static:
            return mig.unpack_static(payload, record.arg_specs)
        out = mig.unpack_dynamic(payload)
        return tuple(out)


def f2f(
    fn: Callable | str,
    *args: Any,
    registry: HandlerRegistry | None = None,
) -> Function:
    """"function to functor": build a transferable closure.

    ``fn`` must already be a registered handler (its registration is the
    analogue of the template instantiation happening in every binary).
    Arguments are validated against the handler's static spec *now*, at
    construction — the paper's compile-time ``is_bitwise_copyable`` trap.
    """
    reg = registry or default_registry()
    record = reg.table.record_of(fn)
    if record.is_static:
        if len(args) != len(record.arg_specs):
            raise SpecMismatchError(
                f"{record.stable_name}: expected {len(record.arg_specs)} args, "
                f"got {len(args)}"
            )
        for a, s in zip(args, record.arg_specs):
            mig.check_against_spec(a, s)
    else:
        for a in args:
            # dynamic path still requires migratable leaves; fail fast here
            mig.pack_dynamic(a) if not mig.is_bitwise_migratable(a) else None
    return Function(record, args)


def l2f(
    name: str,
    fn: Callable,
    *,
    args: tuple | None = None,
    registry: HandlerRegistry | None = None,
) -> Callable:
    """"lambda to functor": register an anonymous function under an explicit
    stable name (paper §5.1 — the route around compiler-internal lambda
    names), returning the function for later ``f2f`` use.

    Must be called during the registration phase (before ``init()``), in
    *every* process, with the same ``name`` — the same-source assumption.
    """
    reg = registry or default_registry()
    specs = tuple(mig.spec_of(a) for a in args) if args is not None else None
    reg.register(fn, arg_specs=specs, name=name)
    return fn
