"""Execution policies (paper §4.3): how a received active message is run.

"In its most basic implementation the policy will simply execute the message
by calling its call operator, while a more sophisticated runtime might for
instance use a policy that puts the message into a queue for a pool of worker
threads."  — we provide exactly those three policies.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable

Task = Callable[[], Any]


class ExecutionPolicy:
    """Interface: ``submit`` a zero-arg task executing one active message."""

    def submit(self, task: Task) -> None:
        raise NotImplementedError

    def drain(self) -> int:
        """Run queued work to completion (no-op for eager policies)."""
        return 0

    def shutdown(self) -> None:
        pass


class DirectPolicy(ExecutionPolicy):
    """Execute inline on the receiving thread — the paper's basic policy.

    Lowest latency; used for the offload-overhead microbenchmarks.
    """

    def submit(self, task: Task) -> None:
        task()


class QueuePolicy(ExecutionPolicy):
    """Enqueue; an owner thread drains explicitly (cooperative runtimes)."""

    def __init__(self):
        self._q: queue.SimpleQueue[Task] = queue.SimpleQueue()

    def submit(self, task: Task) -> None:
        self._q.put(task)

    def drain(self) -> int:
        n = 0
        while True:
            try:
                task = self._q.get_nowait()
            except queue.Empty:
                return n
            task()
            n += 1


class ThreadPoolPolicy(ExecutionPolicy):
    """Worker-pool policy — the paper's "more sophisticated runtime"."""

    def __init__(self, num_workers: int = 2, name: str = "ham-exec"):
        self._q: queue.SimpleQueue[Task | None] = queue.SimpleQueue()
        self._workers = [
            threading.Thread(target=self._run, name=f"{name}-{i}", daemon=True)
            for i in range(num_workers)
        ]
        self._idle = threading.Semaphore(0)
        self._submitted = 0
        self._lock = threading.Lock()
        for w in self._workers:
            w.start()

    def _run(self) -> None:
        while True:
            task = self._q.get()
            if task is None:
                return
            try:
                task()
            finally:
                self._idle.release()

    def submit(self, task: Task) -> None:
        with self._lock:
            self._submitted += 1
        self._q.put(task)

    def drain(self) -> int:
        """Block until every submitted task has finished."""
        with self._lock:
            n = self._submitted
            self._submitted = 0
        for _ in range(n):
            self._idle.acquire()
        return n

    def shutdown(self) -> None:
        for _ in self._workers:
            self._q.put(None)
        for w in self._workers:
            w.join(timeout=5)
