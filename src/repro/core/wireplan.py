"""Compiled per-handler wire plans — the static-payload fast path.

Paper mapping (§4.3): a static-spec handler's argument (and result) layout is
part of the *message type*, known to both sides at registration time.  The
generic :func:`repro.core.migratable.pack_static` walks the spec tuple per
message — isinstance dispatch, ``str(dtype)`` comparisons, one ``struct``
call per scalar leaf.  A :class:`WirePlan` hoists that walk to
``HandlerTable`` init: the spec tuple is compiled **once** into

* one fused :class:`struct.Struct` per *run* of consecutive scalar leaves
  (an all-scalar spec becomes a single ``pack_into``/``unpack_from``),
* fixed ``(offset, nbytes, dtype, shape)`` extents for array leaves
  (encode = one slice copy, decode = one zero-copy ``np.frombuffer`` view),
* fixed extents + codec hooks for opaque leaves,

plus the exact ``payload_nbytes`` — so the per-message cost is one closure
call, no spec traversal.  The wire layout is byte-identical to
``pack_static`` (raw leaf concatenation, little-endian), which is what makes
the ``FLAG_STATIC`` header bit *informational*: a plan-packed frame decodes
with ``unpack_static`` and vice versa (wire compat with pre-plan peers).

Result plans reuse the same layout with an arity convention mirroring
Python returns: ``result_specs=()`` ⇒ the handler returns ``None`` (0-byte
reply), one spec ⇒ the bare value, N specs ⇒ an N-tuple.
"""

from __future__ import annotations

import struct

import numpy as np

from repro.core.errors import MigratableError, SpecMismatchError
from repro.core.migratable import (
    _CODECS_BY_NAME,
    ArraySpec,
    OpaqueSpec,
    ScalarSpec,
    static_payload_nbytes,
)

_SCALAR_FMT = {"i8": "q", "f8": "d", "b1": "?"}
#: pack-side coercions matching ``pack_static`` (np scalars, bools, ints all
#: land on the pinned fixed-width wire types)
_SCALAR_CONV = {"i8": int, "f8": float, "b1": bool}


class _ScalarRun:
    """A run of consecutive scalar leaves fused into one struct."""

    __slots__ = ("offset", "st", "convs", "n")

    def __init__(self, offset: int, kinds: list[str]):
        self.offset = offset
        self.st = struct.Struct("<" + "".join(_SCALAR_FMT[k] for k in kinds))
        self.convs = tuple(_SCALAR_CONV[k] for k in kinds)
        self.n = len(kinds)


class _ArrayLeaf:
    __slots__ = ("offset", "nbytes", "shape", "dtype", "reshape")

    def __init__(self, offset: int, spec: ArraySpec):
        self.offset = offset
        self.nbytes = spec.nbytes
        self.shape = spec.shape
        self.dtype = np.dtype(spec.dtype)
        n = self.nbytes // self.dtype.itemsize
        self.reshape = self.shape != (n,)


class _OpaqueLeaf:
    __slots__ = ("offset", "nbytes", "type_name")

    def __init__(self, offset: int, spec: OpaqueSpec):
        self.offset = offset
        self.nbytes = spec.nbytes_fixed
        self.type_name = spec.type_name

    def _codec(self):
        codec = _CODECS_BY_NAME.get(self.type_name)
        if codec is None:
            raise MigratableError(
                f"no codec registered locally for {self.type_name}; "
                "heterogeneous processes must register the same migratable "
                "specialisations (same-source assumption)"
            )
        return codec

    def pack(self, buf, base: int, args, i: int) -> None:
        raw = self._codec().encode(args[i])
        if len(raw) != self.nbytes:
            raise SpecMismatchError(
                f"codec {self.type_name} produced {len(raw)} bytes, "
                f"spec says {self.nbytes}"
            )
        off = base + self.offset
        buf[off : off + self.nbytes] = raw

    def unpack_one(self, view):
        return self._codec().decode(
            bytes(view[self.offset : self.offset + self.nbytes])
        )


def _compile_ops(specs):
    ops = []
    off = 0
    run_kinds: list[str] = []
    run_off = 0
    for spec in specs:
        if isinstance(spec, ScalarSpec):
            if not run_kinds:
                run_off = off
            run_kinds.append(spec.kind)
            off += spec.nbytes
            continue
        if run_kinds:
            ops.append(_ScalarRun(run_off, run_kinds))
            run_kinds = []
        if isinstance(spec, ArraySpec):
            ops.append(_ArrayLeaf(off, spec))
        elif isinstance(spec, OpaqueSpec):
            ops.append(_OpaqueLeaf(off, spec))
        else:
            raise MigratableError(f"unknown spec {spec!r}")
        off += spec.nbytes
    if run_kinds:
        ops.append(_ScalarRun(run_off, run_kinds))
    return ops, off


def _raise_nargs(expected: int, got: int):
    raise SpecMismatchError(f"expected {expected} args, got {got}")


def _raise_short(expected: int, got: int):
    raise SpecMismatchError(f"static payload too short: {got} < {expected}")


def _raise_scalar(e):
    raise SpecMismatchError(f"scalar leaf pack failed: {e}") from None


def _raise_array(leaf: _ArrayLeaf, arr):
    raise SpecMismatchError(
        f"array leaf mismatch: expected {leaf.dtype}{leaf.shape}, "
        f"got {arr.dtype}{tuple(arr.shape)}"
    )


def _gen_codecs(specs, ops, nbytes):
    """exec-generate straight-line ``pack(buf, off, args)`` and
    ``unpack(view)`` functions for a spec tuple.

    This is the "compiled" in compiled wire plans: the per-message cost is
    one specialised function whose body is the layout — no spec traversal,
    no per-leaf dispatch, every helper pre-bound in the closure namespace
    (the same technique ``collections.namedtuple`` uses).  Opaque leaves
    keep calling their leaf op (codec resolution stays lazy).
    """
    ns = {
        "_np": np,
        "_frombuffer": np.frombuffer,
        "_ndarray": np.ndarray,
        "_asarray": np.asarray,
        "_ascontig": np.ascontiguousarray,
        "_copyto": np.copyto,
        "_uint8": np.uint8,
        "_struct_error": struct.error,
        "_raise_nargs": _raise_nargs,
        "_raise_short": _raise_short,
        "_raise_scalar": _raise_scalar,
        "_raise_array": _raise_array,
    }
    pack_lines = [
        "def _pack(buf, off, args):",
        f"    if len(args) != {len(specs)}: _raise_nargs({len(specs)}, len(args))",
    ]
    unpack_parts: list[str] = []
    i = 0
    for k, op in enumerate(ops):
        if isinstance(op, _ScalarRun):
            ns[f"_p{k}"] = op.st.pack_into
            ns[f"_u{k}"] = op.st.unpack_from
            vals = []
            for j, conv in enumerate(op.convs):
                cname = f"_c{k}_{j}"
                ns[cname] = conv
                vals.append(f"{cname}(args[{i + j}])")
            pack_lines += [
                "    try:",
                f"        _p{k}(buf, off + {op.offset}, {', '.join(vals)})",
                "    except (_struct_error, TypeError, ValueError) as e:",
                "        _raise_scalar(e)",
            ]
            unpack_parts.append(f"*_u{k}(view, {op.offset})")
            i += op.n
        elif isinstance(op, _ArrayLeaf):
            ns[f"_leaf{k}"] = op
            ns[f"_dt{k}"] = op.dtype
            pack_lines += [
                f"    a = args[{i}]",
                "    if not isinstance(a, _ndarray): a = _asarray(a)",
                "    d = a.dtype",
                f"    if (d is not _dt{k} and d != _dt{k}) "
                f"or a.shape != {op.shape!r}: _raise_array(_leaf{k}, a)",
            ]
            if op.nbytes <= 4096:
                # small leaf: one C-level tobytes + slice assign beats
                # building two view arrays (and handles non-contiguous
                # inputs for free)
                pack_lines.append(
                    f"    buf[off + {op.offset} : off + {op.offset + op.nbytes}]"
                    " = a.tobytes()"
                )
            else:
                # big leaf: single copy straight into the frame, no
                # temporary — frombuffer rather than slice assignment
                # (bytearray slices reject ndarrays)
                pack_lines += [
                    "    if not a.flags.c_contiguous: a = _ascontig(a)",
                    f"    _copyto(_frombuffer(buf, _uint8, {op.nbytes}, "
                    f"off + {op.offset}), a.view(_uint8).reshape(-1))",
                ]
            count = op.nbytes // op.dtype.itemsize
            expr = f"_frombuffer(view, _dt{k}, {count}, {op.offset})"
            if op.reshape:
                expr += f".reshape({op.shape!r})"
            unpack_parts.append(expr)
            i += 1
        else:  # _OpaqueLeaf: codec resolution stays lazy behind the op
            ns[f"_leaf{k}"] = op
            pack_lines.append(f"    _leaf{k}.pack(buf, off, args, {i})")
            unpack_parts.append(f"_leaf{k}.unpack_one(view)")
            i += 1
    body = ", ".join(unpack_parts)
    unpack_lines = [
        "def _unpack(view):",
        f"    if len(view) < {nbytes}: _raise_short({nbytes}, len(view))",
        f"    return ({body}{',' if len(unpack_parts) == 1 else ''})",
    ]
    if not unpack_parts:
        unpack_lines[-1] = "    return ()"
    exec("\n".join(pack_lines), ns)          # noqa: S102 — trusted codegen
    exec("\n".join(unpack_lines), ns)        # noqa: S102
    return ns["_pack"], ns["_unpack"]


class WirePlan:
    """Precompiled codec for one static spec tuple (see module docs).

    ``pack_args``/``unpack_args`` are exec-generated straight-line functions
    specialised to the layout; the ``*_result`` variants apply the
    result-arity convention on the same layout.  Array leaves decode as
    zero-copy views into the payload — the caller owns the lifetime rule
    (copy anything that outlives the frame).
    """

    __slots__ = ("specs", "nbytes", "n_args", "_ops", "_solo_st",
                 "_solo_conv", "pack_args", "unpack_args")

    def __init__(self, specs: tuple):
        self.specs = tuple(specs)
        self._ops, self.nbytes = _compile_ops(self.specs)
        assert self.nbytes == static_payload_nbytes(self.specs)
        self.n_args = len(self.specs)
        self.pack_args, self.unpack_args = _gen_codecs(
            self.specs, self._ops, self.nbytes
        )
        # hottest result shape: a single scalar (one struct call, no tuple
        # wrapping on the reply hot path)
        if self.n_args == 1 and isinstance(self._ops[0], _ScalarRun):
            self._solo_st = self._ops[0].st
            self._solo_conv = self._ops[0].convs[0]
        else:
            self._solo_st = self._solo_conv = None

    # -- result side (arity convention) ------------------------------------

    def pack_result(self, buf, off: int, result) -> None:
        n = self.n_args
        if n == 1:
            st = self._solo_st
            if st is not None:
                try:
                    st.pack_into(buf, off, self._solo_conv(result))
                except (struct.error, TypeError, ValueError) as e:
                    raise SpecMismatchError(
                        f"scalar result pack failed: {e}"
                    ) from None
                return
            self.pack_args(buf, off, (result,))
        elif n == 0:
            if result is not None:
                raise SpecMismatchError(
                    f"handler declared result_specs=() but returned {result!r}"
                )
        else:
            if not isinstance(result, (tuple, list)):
                raise SpecMismatchError(
                    f"handler declared {n} result leaves but returned "
                    f"{type(result).__name__}"
                )
            self.pack_args(buf, off, result)

    def unpack_result(self, payload):
        n = self.n_args
        if n == 0:
            return None
        st = self._solo_st
        if st is not None:
            return st.unpack_from(payload, 0)[0]
        values = self.unpack_args(payload)
        return values[0] if n == 1 else values


def compile_plan(specs) -> WirePlan | None:
    """``None`` specs (dynamic handler side) compile to no plan."""
    return None if specs is None else WirePlan(specs)


# ---------------------------------------------------------------------------
# Shape-keyed plan cache (the FLAG_SHAPED dynamic fast path)
# ---------------------------------------------------------------------------
#
# Dynamic handlers have no registered spec, so every call used to walk the
# TLV codec per leaf (~25 µs of interpreter for a small pytree).  But real
# dynamic traffic repeats its *shape* call-to-call: same scalars, same array
# dtypes/shapes, different values.  spec_of() already maps a value to a
# hashable frozen Spec, so the value tuple's spec tuple is a cache key, and
# a cached exec-generated WirePlan gives repeat shapes the same
# straight-line pack/unpack as static specs.
#
# The wire carries a compact *signature* so the receiver can rebuild (and
# cache) the identical plan without any registration handshake:
#
#     signature := arity_tag canonical_spec_string
#     arity_tag := "A"   args tuple        (request: unpack -> tuple)
#                | "V"   bare value        (reply: unpack -> values[0])
#                | "T"   tuple result      (reply: unpack -> tuple)
#
# The tag disambiguates the one case the spec tuple cannot: a handler that
# returned a 1-tuple vs a bare value.  ``None`` results and shapes the spec
# grammar cannot express (str/bytes/lists/dicts/None leaves) stay on TLV —
# FLAG_SHAPED is an opportunistic overlay, never a requirement.

_SIG_ARITIES = ("A", "V", "T")
_SIG_LEAF_RE = None  # compiled lazily (re import cost off the hot path)


def spec_signature(specs, arity: str) -> bytes:
    """Wire signature for a spec tuple (grammar above)."""
    if arity not in _SIG_ARITIES:
        raise MigratableError(f"bad signature arity {arity!r}")
    from repro.core.migratable import canonical_spec_string

    return (arity + canonical_spec_string(specs)).encode("ascii")


def parse_signature(sig: bytes) -> tuple[str, tuple]:
    """Inverse of :func:`spec_signature`: ``(arity, spec_tuple)``.

    Raises :class:`MigratableError` on any malformed signature — the caller
    treats that as a protocol error, not a fallback.
    """
    global _SIG_LEAF_RE
    if _SIG_LEAF_RE is None:
        import re

        # leaf tokens never contain ']' internally: scalar kinds are [a-z0-9],
        # dtypes come from str(np.dtype) of a biufc-kind array, opaque names
        # are module:qualname
        _SIG_LEAF_RE = re.compile(
            rb"scalar\[([^\]]*)\]|array\[([^;\]]*);([^\]]*)\]|opaque\[([^;\]]*);(\d+)\]"
        )
    try:
        text = sig.decode("ascii")
    except UnicodeDecodeError as e:
        raise MigratableError(f"undecodable shape signature: {e}") from None
    if not text or text[0] not in _SIG_ARITIES:
        raise MigratableError(f"bad shape signature arity in {text[:32]!r}")
    arity, body = text[0], text[1:]
    if not (body.startswith("(") and body.endswith(")")):
        raise MigratableError(f"bad shape signature body {body[:32]!r}")
    specs = []
    for m in _SIG_LEAF_RE.finditer(sig, 1):
        kind, adtype, dims, oname, onbytes = m.groups()
        if kind is not None:
            if kind.decode() not in _SCALAR_FMT:
                raise MigratableError(f"unknown scalar kind {kind!r}")
            specs.append(ScalarSpec(kind.decode()))
        elif adtype is not None:
            shape = tuple(int(d) for d in dims.split(b",")) if dims else ()
            specs.append(ArraySpec(shape, adtype.decode()))
        else:
            specs.append(OpaqueSpec(oname.decode(), int(onbytes)))
    # reject trailing garbage / unrecognised leaves: rebuilding the body
    # from what parsed must reproduce the wire bytes exactly
    if "(" + ",".join(s.canonical() for s in specs) + ")" != body:
        raise MigratableError(f"unparseable shape signature {body[:64]!r}")
    return arity, tuple(specs)


class ShapeCache:
    """Bounded LRU of shape-keyed :class:`WirePlan` s, both directions.

    Send side keys on the *spec tuple* (derived from live values via
    ``spec_of`` — a few hundred ns for small pytrees); receive side keys on
    the raw signature bytes from the wire.  Entries are tiny (a compiled
    plan + signature), so the default bound of 256 distinct shapes per side
    is generous; eviction is plain LRU under one lock (both hooks are
    called from runtime loop threads *and* user threads).
    """

    __slots__ = ("maxsize", "_lock", "_by_key", "_by_sig",
                 "hits", "misses", "evictions")

    def __init__(self, maxsize: int = 256):
        from collections import OrderedDict
        from threading import Lock

        self.maxsize = maxsize
        self._lock = Lock()
        self._by_key: dict = OrderedDict()   # spec-tuple+arity -> (sig, plan)
        self._by_sig: dict = OrderedDict()   # sig bytes -> (arity, plan)
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- send side ---------------------------------------------------------
    @staticmethod
    def _fast_key(values, arity: str):
        """Hashable shape key without constructing Spec objects (~0.3 µs per
        leaf vs ~3 µs for ``spec_of``).  ``None`` -> take the spec_of path
        (np scalar subtypes, codec'd opaques, array-likes)."""
        key = [arity]
        append = key.append
        for v in values:
            t = type(v)
            if t is int:
                append("i")
            elif t is float:
                append("f")
            elif t is bool:
                append("b")
            elif t is np.ndarray and v.dtype.kind in "biufc":
                append((v.dtype, v.shape))
            else:
                return None
        return tuple(key)

    def for_values(self, values, arity: str):
        """``(signature, plan)`` for a tuple of leaf values, or ``None``
        when any leaf is outside the spec grammar (caller falls back to
        TLV).  ``arity`` is the wire tag ("A"/"V"/"T")."""
        key = self._fast_key(values, arity)
        if key is None:
            from repro.core.migratable import spec_of

            try:
                key = (arity, tuple(spec_of(v) for v in values))
            except MigratableError:
                return None
        with self._lock:
            ent = self._by_key.get(key)
            if ent is not None:
                self._by_key.move_to_end(key)
                self.hits += 1
                return ent
        # miss: derive the authoritative spec tuple (the fast key maps 1:1
        # onto it — exact int/float/bool/ndarray types only)
        from repro.core.migratable import spec_of

        try:
            specs = tuple(spec_of(v) for v in values)
        except MigratableError:
            return None
        sig = spec_signature(specs, arity)
        plan = WirePlan(specs)
        with self._lock:
            self.misses += 1
            self._by_key[key] = (sig, plan)
            if len(self._by_key) > self.maxsize:
                self._by_key.popitem(last=False)
                self.evictions += 1
        return sig, plan

    def for_result(self, result):
        """Shape entry for a reply value (``None``/non-speccable -> TLV)."""
        if result is None:
            return None
        if isinstance(result, tuple):
            return self.for_values(result, "T")
        return self.for_values((result,), "V")

    # -- receive side ------------------------------------------------------
    def for_signature(self, sig: bytes):
        """``(arity, plan)`` for raw signature bytes off the wire.

        Malformed signatures raise :class:`MigratableError` (protocol
        error); unknown-but-wellformed shapes compile and cache."""
        with self._lock:
            ent = self._by_sig.get(sig)
            if ent is not None:
                self._by_sig.move_to_end(sig)
                self.hits += 1
                return ent
        arity, specs = parse_signature(sig)
        plan = WirePlan(specs)
        with self._lock:
            self.misses += 1
            self._by_sig[sig] = (arity, plan)
            if len(self._by_sig) > self.maxsize:
                self._by_sig.popitem(last=False)
                self.evictions += 1
        return arity, plan

    def unpack_shaped(self, payload, *, expect_args: bool):
        """Decode a FLAG_SHAPED payload: u16 sig_len | sig | packed leaves.

        ``expect_args=True`` (request side) returns a tuple regardless of
        tag; the reply side honours the V/T arity convention.
        """
        (sig_len,) = SIG_LEN_STRUCT.unpack_from(payload, 0)
        sig = bytes(payload[2 : 2 + sig_len])
        arity, plan = self.for_signature(sig)
        values = plan.unpack_args(payload[2 + sig_len :])
        if expect_args or arity == "T":
            return values
        if arity == "V":
            return values[0]
        return values  # "A" payload surfacing on the reply path

    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "send_entries": len(self._by_key),
                "recv_entries": len(self._by_sig),
            }


#: length prefix of the signature in a FLAG_SHAPED payload
SIG_LEN_STRUCT = struct.Struct("<H")
SIG_LEN_NBYTES = SIG_LEN_STRUCT.size  # 2


def pack_shaped(sig: bytes, plan: WirePlan, values) -> bytearray:
    """Standalone FLAG_SHAPED payload (the fused-segment path; the
    standalone-frame path packs straight into the frame buffer)."""
    buf = bytearray(SIG_LEN_NBYTES + len(sig) + plan.nbytes)
    SIG_LEN_STRUCT.pack_into(buf, 0, len(sig))
    buf[SIG_LEN_NBYTES : SIG_LEN_NBYTES + len(sig)] = sig
    plan.pack_args(buf, SIG_LEN_NBYTES + len(sig), values)
    return buf
