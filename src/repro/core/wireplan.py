"""Compiled per-handler wire plans — the static-payload fast path.

Paper mapping (§4.3): a static-spec handler's argument (and result) layout is
part of the *message type*, known to both sides at registration time.  The
generic :func:`repro.core.migratable.pack_static` walks the spec tuple per
message — isinstance dispatch, ``str(dtype)`` comparisons, one ``struct``
call per scalar leaf.  A :class:`WirePlan` hoists that walk to
``HandlerTable`` init: the spec tuple is compiled **once** into

* one fused :class:`struct.Struct` per *run* of consecutive scalar leaves
  (an all-scalar spec becomes a single ``pack_into``/``unpack_from``),
* fixed ``(offset, nbytes, dtype, shape)`` extents for array leaves
  (encode = one slice copy, decode = one zero-copy ``np.frombuffer`` view),
* fixed extents + codec hooks for opaque leaves,

plus the exact ``payload_nbytes`` — so the per-message cost is one closure
call, no spec traversal.  The wire layout is byte-identical to
``pack_static`` (raw leaf concatenation, little-endian), which is what makes
the ``FLAG_STATIC`` header bit *informational*: a plan-packed frame decodes
with ``unpack_static`` and vice versa (wire compat with pre-plan peers).

Result plans reuse the same layout with an arity convention mirroring
Python returns: ``result_specs=()`` ⇒ the handler returns ``None`` (0-byte
reply), one spec ⇒ the bare value, N specs ⇒ an N-tuple.
"""

from __future__ import annotations

import struct

import numpy as np

from repro.core.errors import MigratableError, SpecMismatchError
from repro.core.migratable import (
    _CODECS_BY_NAME,
    ArraySpec,
    OpaqueSpec,
    ScalarSpec,
    static_payload_nbytes,
)

_SCALAR_FMT = {"i8": "q", "f8": "d", "b1": "?"}
#: pack-side coercions matching ``pack_static`` (np scalars, bools, ints all
#: land on the pinned fixed-width wire types)
_SCALAR_CONV = {"i8": int, "f8": float, "b1": bool}


class _ScalarRun:
    """A run of consecutive scalar leaves fused into one struct."""

    __slots__ = ("offset", "st", "convs", "n")

    def __init__(self, offset: int, kinds: list[str]):
        self.offset = offset
        self.st = struct.Struct("<" + "".join(_SCALAR_FMT[k] for k in kinds))
        self.convs = tuple(_SCALAR_CONV[k] for k in kinds)
        self.n = len(kinds)


class _ArrayLeaf:
    __slots__ = ("offset", "nbytes", "shape", "dtype", "reshape")

    def __init__(self, offset: int, spec: ArraySpec):
        self.offset = offset
        self.nbytes = spec.nbytes
        self.shape = spec.shape
        self.dtype = np.dtype(spec.dtype)
        n = self.nbytes // self.dtype.itemsize
        self.reshape = self.shape != (n,)


class _OpaqueLeaf:
    __slots__ = ("offset", "nbytes", "type_name")

    def __init__(self, offset: int, spec: OpaqueSpec):
        self.offset = offset
        self.nbytes = spec.nbytes_fixed
        self.type_name = spec.type_name

    def _codec(self):
        codec = _CODECS_BY_NAME.get(self.type_name)
        if codec is None:
            raise MigratableError(
                f"no codec registered locally for {self.type_name}; "
                "heterogeneous processes must register the same migratable "
                "specialisations (same-source assumption)"
            )
        return codec

    def pack(self, buf, base: int, args, i: int) -> None:
        raw = self._codec().encode(args[i])
        if len(raw) != self.nbytes:
            raise SpecMismatchError(
                f"codec {self.type_name} produced {len(raw)} bytes, "
                f"spec says {self.nbytes}"
            )
        off = base + self.offset
        buf[off : off + self.nbytes] = raw

    def unpack_one(self, view):
        return self._codec().decode(
            bytes(view[self.offset : self.offset + self.nbytes])
        )


def _compile_ops(specs):
    ops = []
    off = 0
    run_kinds: list[str] = []
    run_off = 0
    for spec in specs:
        if isinstance(spec, ScalarSpec):
            if not run_kinds:
                run_off = off
            run_kinds.append(spec.kind)
            off += spec.nbytes
            continue
        if run_kinds:
            ops.append(_ScalarRun(run_off, run_kinds))
            run_kinds = []
        if isinstance(spec, ArraySpec):
            ops.append(_ArrayLeaf(off, spec))
        elif isinstance(spec, OpaqueSpec):
            ops.append(_OpaqueLeaf(off, spec))
        else:
            raise MigratableError(f"unknown spec {spec!r}")
        off += spec.nbytes
    if run_kinds:
        ops.append(_ScalarRun(run_off, run_kinds))
    return ops, off


def _raise_nargs(expected: int, got: int):
    raise SpecMismatchError(f"expected {expected} args, got {got}")


def _raise_short(expected: int, got: int):
    raise SpecMismatchError(f"static payload too short: {got} < {expected}")


def _raise_scalar(e):
    raise SpecMismatchError(f"scalar leaf pack failed: {e}") from None


def _raise_array(leaf: _ArrayLeaf, arr):
    raise SpecMismatchError(
        f"array leaf mismatch: expected {leaf.dtype}{leaf.shape}, "
        f"got {arr.dtype}{tuple(arr.shape)}"
    )


def _gen_codecs(specs, ops, nbytes):
    """exec-generate straight-line ``pack(buf, off, args)`` and
    ``unpack(view)`` functions for a spec tuple.

    This is the "compiled" in compiled wire plans: the per-message cost is
    one specialised function whose body is the layout — no spec traversal,
    no per-leaf dispatch, every helper pre-bound in the closure namespace
    (the same technique ``collections.namedtuple`` uses).  Opaque leaves
    keep calling their leaf op (codec resolution stays lazy).
    """
    ns = {
        "_np": np,
        "_frombuffer": np.frombuffer,
        "_ndarray": np.ndarray,
        "_asarray": np.asarray,
        "_ascontig": np.ascontiguousarray,
        "_copyto": np.copyto,
        "_uint8": np.uint8,
        "_struct_error": struct.error,
        "_raise_nargs": _raise_nargs,
        "_raise_short": _raise_short,
        "_raise_scalar": _raise_scalar,
        "_raise_array": _raise_array,
    }
    pack_lines = [
        "def _pack(buf, off, args):",
        f"    if len(args) != {len(specs)}: _raise_nargs({len(specs)}, len(args))",
    ]
    unpack_parts: list[str] = []
    i = 0
    for k, op in enumerate(ops):
        if isinstance(op, _ScalarRun):
            ns[f"_p{k}"] = op.st.pack_into
            ns[f"_u{k}"] = op.st.unpack_from
            vals = []
            for j, conv in enumerate(op.convs):
                cname = f"_c{k}_{j}"
                ns[cname] = conv
                vals.append(f"{cname}(args[{i + j}])")
            pack_lines += [
                "    try:",
                f"        _p{k}(buf, off + {op.offset}, {', '.join(vals)})",
                "    except (_struct_error, TypeError, ValueError) as e:",
                "        _raise_scalar(e)",
            ]
            unpack_parts.append(f"*_u{k}(view, {op.offset})")
            i += op.n
        elif isinstance(op, _ArrayLeaf):
            ns[f"_leaf{k}"] = op
            ns[f"_dt{k}"] = op.dtype
            pack_lines += [
                f"    a = args[{i}]",
                "    if not isinstance(a, _ndarray): a = _asarray(a)",
                "    d = a.dtype",
                f"    if (d is not _dt{k} and d != _dt{k}) "
                f"or a.shape != {op.shape!r}: _raise_array(_leaf{k}, a)",
            ]
            if op.nbytes <= 4096:
                # small leaf: one C-level tobytes + slice assign beats
                # building two view arrays (and handles non-contiguous
                # inputs for free)
                pack_lines.append(
                    f"    buf[off + {op.offset} : off + {op.offset + op.nbytes}]"
                    " = a.tobytes()"
                )
            else:
                # big leaf: single copy straight into the frame, no
                # temporary — frombuffer rather than slice assignment
                # (bytearray slices reject ndarrays)
                pack_lines += [
                    "    if not a.flags.c_contiguous: a = _ascontig(a)",
                    f"    _copyto(_frombuffer(buf, _uint8, {op.nbytes}, "
                    f"off + {op.offset}), a.view(_uint8).reshape(-1))",
                ]
            count = op.nbytes // op.dtype.itemsize
            expr = f"_frombuffer(view, _dt{k}, {count}, {op.offset})"
            if op.reshape:
                expr += f".reshape({op.shape!r})"
            unpack_parts.append(expr)
            i += 1
        else:  # _OpaqueLeaf: codec resolution stays lazy behind the op
            ns[f"_leaf{k}"] = op
            pack_lines.append(f"    _leaf{k}.pack(buf, off, args, {i})")
            unpack_parts.append(f"_leaf{k}.unpack_one(view)")
            i += 1
    body = ", ".join(unpack_parts)
    unpack_lines = [
        "def _unpack(view):",
        f"    if len(view) < {nbytes}: _raise_short({nbytes}, len(view))",
        f"    return ({body}{',' if len(unpack_parts) == 1 else ''})",
    ]
    if not unpack_parts:
        unpack_lines[-1] = "    return ()"
    exec("\n".join(pack_lines), ns)          # noqa: S102 — trusted codegen
    exec("\n".join(unpack_lines), ns)        # noqa: S102
    return ns["_pack"], ns["_unpack"]


class WirePlan:
    """Precompiled codec for one static spec tuple (see module docs).

    ``pack_args``/``unpack_args`` are exec-generated straight-line functions
    specialised to the layout; the ``*_result`` variants apply the
    result-arity convention on the same layout.  Array leaves decode as
    zero-copy views into the payload — the caller owns the lifetime rule
    (copy anything that outlives the frame).
    """

    __slots__ = ("specs", "nbytes", "n_args", "_ops", "_solo_st",
                 "_solo_conv", "pack_args", "unpack_args")

    def __init__(self, specs: tuple):
        self.specs = tuple(specs)
        self._ops, self.nbytes = _compile_ops(self.specs)
        assert self.nbytes == static_payload_nbytes(self.specs)
        self.n_args = len(self.specs)
        self.pack_args, self.unpack_args = _gen_codecs(
            self.specs, self._ops, self.nbytes
        )
        # hottest result shape: a single scalar (one struct call, no tuple
        # wrapping on the reply hot path)
        if self.n_args == 1 and isinstance(self._ops[0], _ScalarRun):
            self._solo_st = self._ops[0].st
            self._solo_conv = self._ops[0].convs[0]
        else:
            self._solo_st = self._solo_conv = None

    # -- result side (arity convention) ------------------------------------

    def pack_result(self, buf, off: int, result) -> None:
        n = self.n_args
        if n == 1:
            st = self._solo_st
            if st is not None:
                try:
                    st.pack_into(buf, off, self._solo_conv(result))
                except (struct.error, TypeError, ValueError) as e:
                    raise SpecMismatchError(
                        f"scalar result pack failed: {e}"
                    ) from None
                return
            self.pack_args(buf, off, (result,))
        elif n == 0:
            if result is not None:
                raise SpecMismatchError(
                    f"handler declared result_specs=() but returned {result!r}"
                )
        else:
            if not isinstance(result, (tuple, list)):
                raise SpecMismatchError(
                    f"handler declared {n} result leaves but returned "
                    f"{type(result).__name__}"
                )
            self.pack_args(buf, off, result)

    def unpack_result(self, payload):
        n = self.n_args
        if n == 0:
            return None
        st = self._solo_st
        if st is not None:
            return st.unpack_from(payload, 0)[0]
        values = self.unpack_args(payload)
        return values[0] if n == 1 else values


def compile_plan(specs) -> WirePlan | None:
    """``None`` specs (dynamic handler side) compile to no plan."""
    return None if specs is None else WirePlan(specs)
