"""qwen1.5-4b [dense]: 40L d=2560 20H (kv=20) d_ff=6912 vocab=151936, QKV
bias [hf:Qwen/Qwen1.5-*]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense", num_layers=40, d_model=2560,
    num_heads=20, num_kv_heads=20, d_ff=6912, vocab_size=151936,
    qkv_bias=True, mlp="swiglu", rope_theta=1_000_000.0,
)

REDUCED = ModelConfig(
    name="qwen1.5-4b-reduced", family="dense", num_layers=2, d_model=40,
    num_heads=5, num_kv_heads=5, d_ff=96, vocab_size=128,
    qkv_bias=True, dtype="float32", param_dtype="float32", remat="none",
)
