"""internvl2-76b [vlm]: 80L d=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 —
InternViT frontend is a STUB (precomputed patch embeddings)
[arXiv:2404.16821]."""
from repro.models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=28672, vocab_size=128256,
    mlp="swiglu", rope_theta=1_000_000.0, vlm=VLMConfig(num_patches=256),
)

REDUCED = ModelConfig(
    name="internvl2-76b-reduced", family="vlm", num_layers=2, d_model=64,
    num_heads=8, num_kv_heads=2, d_ff=128, vocab_size=128,
    dtype="float32", param_dtype="float32", remat="none",
    vlm=VLMConfig(num_patches=4),
)
