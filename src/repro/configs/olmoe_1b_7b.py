"""olmoe-1b-7b [moe]: 16L d=2048 16H d_ff=1024/expert, 64 experts top-8
[arXiv:2409.02060]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", num_layers=16, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1024, vocab_size=50304,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024,
                  capacity_factor=1.25, expert_parallel=True),
)

REDUCED = ModelConfig(
    name="olmoe-1b-7b-reduced", family="moe", num_layers=2, d_model=32,
    num_heads=4, num_kv_heads=4, d_ff=16, vocab_size=128,
    dtype="float32", param_dtype="float32", remat="none",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16,
                  capacity_factor=2.0, expert_parallel=True),
)
