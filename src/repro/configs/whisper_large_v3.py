"""whisper-large-v3 [audio]: enc-dec 32L d=1280 20H d_ff=5120 vocab=51866;
conv/mel frontend is a STUB (precomputed 1500-frame embeddings)
[arXiv:2212.04356]."""
from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio", num_layers=32, d_model=1280,
    num_heads=20, num_kv_heads=20, d_ff=5120, vocab_size=51866,
    mlp="gelu", encdec=EncDecConfig(encoder_layers=32, encoder_frames=1500),
)

REDUCED = ModelConfig(
    name="whisper-large-v3-reduced", family="audio", num_layers=2, d_model=40,
    num_heads=4, num_kv_heads=4, d_ff=80, vocab_size=128,
    mlp="gelu", dtype="float32", param_dtype="float32", remat="none",
    encdec=EncDecConfig(encoder_layers=2, encoder_frames=16),
)
