"""xlstm-1.3b [ssm]: 48L d_model=2048 4H, vocab 50304 — sLSTM + mLSTM 7:1
[arXiv:2405.04517]."""
from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", num_layers=48, d_model=2048,
    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
    xlstm=XLSTMConfig(mlstm_per_group=7, slstm_per_group=1, chunk_size=256,
                      proj_factor=2.0, conv_width=4),
)

REDUCED = ModelConfig(
    name="xlstm-1.3b-reduced", family="ssm", num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=128,
    dtype="float32", param_dtype="float32", remat="none",
    xlstm=XLSTMConfig(mlstm_per_group=3, slstm_per_group=1, chunk_size=8,
                      proj_factor=2.0, conv_width=4),
)
