"""Assigned-architecture configs: ``--arch <id>`` resolves here."""

from repro.configs import (
    internlm2_20b,
    internvl2_76b,
    llama3_405b,
    nemotron4_340b,
    olmoe_1b_7b,
    qwen15_4b,
    qwen2_moe_a2p7b,
    whisper_large_v3,
    xlstm_1p3b,
    zamba2_2p7b,
)

_MODULES = {
    "xlstm-1.3b": xlstm_1p3b,
    "internlm2-20b": internlm2_20b,
    "qwen1.5-4b": qwen15_4b,
    "llama3-405b": llama3_405b,
    "nemotron-4-340b": nemotron4_340b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "qwen2-moe-a2.7b": qwen2_moe_a2p7b,
    "internvl2-76b": internvl2_76b,
    "zamba2-2.7b": zamba2_2p7b,
    "whisper-large-v3": whisper_large_v3,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str):
    return _MODULES[arch_id].CONFIG


def get_reduced(arch_id: str):
    return _MODULES[arch_id].REDUCED
