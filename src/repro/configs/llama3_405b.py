"""llama3-405b [dense]: 126L d=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 [arXiv:2407.21783]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense", num_layers=126, d_model=16384,
    num_heads=128, num_kv_heads=8, d_ff=53248, vocab_size=128256,
    mlp="swiglu", rope_theta=500_000.0,
)

REDUCED = ModelConfig(
    name="llama3-405b-reduced", family="dense", num_layers=3, d_model=64,
    num_heads=8, num_kv_heads=2, d_ff=192, vocab_size=128,
    dtype="float32", param_dtype="float32", remat="none",
)
