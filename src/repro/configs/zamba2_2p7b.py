"""zamba2-2.7b [hybrid]: 54L d=2560, Mamba2 (ssm_state=64) + shared attn
block (32H kv=32, d_ff=10240) every 6 layers [arXiv:2411.15242]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, num_groups=1, chunk_size=256,
                  conv_width=4, expand=2, attn_every=6, attn_window=None),
)

REDUCED = ModelConfig(
    name="zamba2-2.7b-reduced", family="hybrid", num_layers=4, d_model=32,
    num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128,
    dtype="float32", param_dtype="float32", remat="none",
    ssm=SSMConfig(state_dim=8, head_dim=8, num_groups=2, chunk_size=8,
                  conv_width=4, expand=2, attn_every=2, attn_window=None),
)
