"""nemotron-4-340b [dense]: 96L d=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU MLP [arXiv:2402.16819]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense", num_layers=96, d_model=18432,
    num_heads=96, num_kv_heads=8, d_ff=73728, vocab_size=256000,
    mlp="relu2", rope_theta=10_000.0,
)

REDUCED = ModelConfig(
    name="nemotron-4-340b-reduced", family="dense", num_layers=2, d_model=48,
    num_heads=6, num_kv_heads=2, d_ff=192, vocab_size=128,
    mlp="relu2", dtype="float32", param_dtype="float32", remat="none",
)
