"""qwen2-moe-a2.7b [moe]: 24L d=2048 16H, 60 routed top-4 + 4 shared experts
d_ff=1408 [hf:Qwen/Qwen1.5-MoE-A2.7B].  60 experts do not divide the 16-way
model axis -> TP-in-expert sharding (DESIGN.md §5)."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=151936,
    moe=MoEConfig(num_experts=60, top_k=4, d_ff_expert=1408,
                  num_shared_experts=4, capacity_factor=1.25,
                  expert_parallel=False),
)

REDUCED = ModelConfig(
    name="qwen2-moe-a2.7b-reduced", family="moe", num_layers=2, d_model=32,
    num_heads=4, num_kv_heads=4, d_ff=16, vocab_size=128,
    dtype="float32", param_dtype="float32", remat="none",
    moe=MoEConfig(num_experts=6, top_k=2, d_ff_expert=16,
                  num_shared_experts=2, capacity_factor=2.0,
                  expert_parallel=False),
)
