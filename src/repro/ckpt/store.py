"""Sharded checkpointing with manifest + async writer.

Layout::

    <dir>/step_000042/
        manifest.json      # step, arch, key-map digest, leaf index, mesh
        leaf_00000.npy ... # one array per param/opt leaf (flattened path)

The manifest records the HAM **key-map digest** — a restarted fleet verifies
it derives the same handler keys as the fleet that wrote the checkpoint
(same-source check across restarts, not just across processes).  Saves are
double-buffered onto a background thread (training never blocks on disk);
``wait()`` joins the in-flight save.  Restores are exact (bit-for-bit), which
the restart tests assert.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointStore:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree, *, meta: dict | None = None,
             blocking: bool = False) -> None:
        self.wait()  # one in-flight save at a time (double buffer)
        paths, leaves, _ = _flatten_with_paths(tree)
        host_leaves = [np.asarray(l) for l in leaves]  # device -> host now

        def write():
            try:
                tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
                final = os.path.join(self.dir, f"step_{step:09d}")
                os.makedirs(tmp, exist_ok=True)
                index = []
                for i, (p, arr) in enumerate(zip(paths, host_leaves)):
                    fname = f"leaf_{i:05d}.npy"
                    np.save(os.path.join(tmp, fname), arr)
                    index.append({"path": p, "file": fname,
                                  "shape": list(arr.shape),
                                  "dtype": str(arr.dtype)})
                manifest = {"step": step, "leaves": index}
                manifest.update(meta or {})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)  # atomic publish
                self._gc()
            except BaseException as e:  # noqa: BLE001 — surfaced via wait()
                self._error = e

        if blocking:
            write()
            if self._error:
                raise self._error
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:09d}", "manifest.json")) as f:
            return json.load(f)

    def restore(self, step: int, template):
        """Restore into the structure of ``template`` (exact dtypes/shapes)."""
        d = os.path.join(self.dir, f"step_{step:09d}")
        man = self.manifest(step)
        paths, leaves, treedef = _flatten_with_paths(template)
        by_path = {e["path"]: e for e in man["leaves"]}
        out = []
        for p, leaf in zip(paths, leaves):
            e = by_path.get(p)
            if e is None:
                raise KeyError(f"checkpoint missing leaf {p!r}")
            arr = np.load(os.path.join(d, e["file"]))
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"leaf {p!r}: checkpoint shape {arr.shape} != template "
                    f"{tuple(leaf.shape)} (elastic reshard not yet applied)"
                )
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)
