"""Trainer: checkpointed training loop, controllable over HAM.

The loop itself is ordinary JAX; what HAM adds is the *control plane*:
``Trainer.register_handlers()`` exposes run/pause/checkpoint/metrics as
active messages, so a host (or any peer — reverse offload) can drive a
training worker exactly the way HAM-Offload drives an accelerator.  The
same handlers back the fault-tolerance machinery in ``train.ft``.
"""

from __future__ import annotations

import time
from typing import Any, Callable

import jax
import numpy as np

from repro.ckpt.store import CheckpointStore
from repro.core.registry import default_registry
from repro.data.pipeline import DataConfig, SyntheticTokens, batch_for_model
from repro.models.api import build_model
from repro.optim import adamw
from repro.train.step import build_train_step


class Trainer:
    def __init__(
        self,
        cfg,
        opt_cfg: adamw.AdamWConfig | None = None,
        *,
        ckpt_dir: str | None = None,
        ckpt_every: int = 50,
        data_seed: int = 0,
        global_batch: int = 8,
        seq_len: int = 64,
        shard: int = 0,
        num_shards: int = 1,
        sharder=None,
    ):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.sharder = sharder
        self.data = SyntheticTokens(
            DataConfig(cfg.vocab_size, seq_len, global_batch, seed=data_seed),
            shard=shard, num_shards=num_shards,
        )
        self.step_fn = jax.jit(
            build_train_step(self.model, self.opt_cfg, sharder),
            donate_argnums=(0, 1),
        )
        self.store = CheckpointStore(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.params = None
        self.opt_state = None
        self.step = 0
        self.metrics_history: list[dict] = []
        self._stop_requested = False

    # -- lifecycle -------------------------------------------------------------

    def init(self, seed: int = 0) -> None:
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.opt_state = adamw.init(self.params)
        self.step = 0

    def maybe_restore(self) -> bool:
        """Restart path: resume from the latest checkpoint if one exists."""
        if self.store is None:
            return False
        latest = self.store.latest_step()
        if latest is None:
            return False
        if self.params is None:
            self.init()
        man = self.store.manifest(latest)
        reg = default_registry()
        if reg.initialised and "key_digest" in man:
            if man["key_digest"] != reg.table.digest.hex():
                raise RuntimeError(
                    "checkpoint written by a fleet with a different HAM "
                    "key map (same-source violation across restart)"
                )
        tree = self.store.restore(latest, {"params": self.params,
                                           "opt": self.opt_state})
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = latest
        return True

    def checkpoint(self, blocking: bool = False) -> None:
        if self.store is None:
            return
        reg = default_registry()
        meta = {"arch": self.cfg.name}
        if reg.initialised:
            meta["key_digest"] = reg.table.digest.hex()
        self.store.save(self.step, {"params": self.params, "opt": self.opt_state},
                        meta=meta, blocking=blocking)

    # -- stepping ---------------------------------------------------------------

    def run_steps(self, n: int) -> dict:
        if self.params is None:
            self.init()
        t0 = time.perf_counter()
        last = {}
        for _ in range(n):
            if self._stop_requested:
                break
            batch = batch_for_model(self.data, self.cfg, self.step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            self.step += 1
            last = {k: float(v) for k, v in metrics.items()}
            last["step"] = self.step
            self.metrics_history.append(last)
            if self.store is not None and self.step % self.ckpt_every == 0:
                self.checkpoint()
        last["wall_s"] = time.perf_counter() - t0
        return last

    def latest_metrics(self) -> dict:
        return self.metrics_history[-1] if self.metrics_history else {}

    # -- HAM control plane --------------------------------------------------------

    def register_handlers(self, registry=None, prefix: str = "train") -> None:
        """Expose the trainer as offloadable handlers (call before init())."""
        reg = registry or default_registry()
        reg.register(lambda n: self.run_steps(int(n)), name=f"{prefix}/run_steps")
        reg.register(lambda: self.latest_metrics(), name=f"{prefix}/metrics")
        reg.register(lambda: (self.checkpoint(blocking=True), self.step)[1],
                     name=f"{prefix}/checkpoint_now")
        reg.register(lambda: self.stop(), name=f"{prefix}/stop")
        reg.register(lambda: self.step, name=f"{prefix}/step")

    def stop(self) -> None:
        self._stop_requested = True
