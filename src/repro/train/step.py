"""Train-step builders (the functions the dry-run lowers and the trainer
jits).  Pure: (params, opt_state, batch) -> (params, opt_state, metrics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.optim.compression import ef_compress_tree, ef_decompress_tree


def build_train_step(model, opt_cfg: adamw.AdamWConfig, sharder=None,
                     grad_shardings=None):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch, sharder
        )
        if grad_shardings is not None:
            # ZeRO-2: pin gradients to the parameter shards so GSPMD emits
            # reduce-scatters over the batch axes instead of full
            # all-reduce + slice (16x less DP traffic under FSDP)
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        if opt_cfg.reduce_dtype is not None:
            # distributed-optimisation trick: the DP gradient reduction
            # happens in reduced precision — under GSPMD the psum that
            # materialises on the batch axes then moves half the bytes
            rd = jnp.dtype(opt_cfg.reduce_dtype)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(rd).astype(jnp.float32), grads
            )
        params, opt_state, om = adamw.update(opt_cfg, params, opt_state, grads)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def build_compressed_train_step(model, opt_cfg: adamw.AdamWConfig, sharder=None):
    """Variant with in-graph int8 error-feedback gradient compression —
    state carries the EF residual (ablated in tests for convergence)."""

    def train_step(params, opt_state, ef_residual, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch, sharder
        )
        qtree, ef_residual = ef_compress_tree(grads, ef_residual)
        grads = ef_decompress_tree(qtree)
        params, opt_state, om = adamw.update(opt_cfg, params, opt_state, grads)
        return params, opt_state, ef_residual, {"loss": loss, **metrics, **om}

    return train_step


def build_eval_step(model, sharder=None):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch, sharder)
        return {"loss": loss, **metrics}

    return eval_step
