"""Fault tolerance over active messages: heartbeats, stragglers, elasticity.

Everything here is built from the paper's primitives — no side channels:

* **Heartbeats** are ``_ham/ping`` round-trips; a missed deadline marks the
  node dead, fails its outstanding futures, and fires the rescale callback.
* **Straggler detection** aggregates per-node step timings (reported as
  active messages by workers) and flags nodes slower than
  ``factor × median``; the mitigation hook can reroute their shards or pad
  their serving steps with the ``serve/noop`` handler (device-table branch).
* **Elastic membership** is where the paper's key insight pays off at pod
  scale: keys are derived *locally* from sorted stable names, so a joining
  or surviving fleet agrees on every handler key with zero negotiation —
  rescaling is: verify digest (32 bytes), reassign data shards, continue
  from the latest checkpoint.  No global re-registration round.
"""

from __future__ import annotations

import threading
import time
from typing import Callable

from repro.core.closure import f2f
from repro.core.errors import KeyMapMismatchError, NodeDownError


class HeartbeatMonitor:
    """Host-side liveness tracking for a set of worker nodes."""

    def __init__(
        self,
        domain,
        nodes: list[int],
        *,
        interval: float = 0.2,
        timeout: float = 1.0,
        on_failure: Callable[[int], None] | None = None,
    ):
        self.domain = domain
        self.nodes = set(nodes)
        self.interval = interval
        self.timeout = timeout
        self.on_failure = on_failure
        self.last_seen: dict[int, float] = {n: time.monotonic() for n in nodes}
        self.dead: set[int] = set()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _beat_once(self) -> None:
        now = time.monotonic()
        for n in sorted(self.nodes - self.dead):
            fut = self.domain.async_(n, f2f("_ham/ping", 0,
                                            registry=self.domain.registry))

            def made(node):
                def cb(f):
                    try:
                        f.get(0)
                        self.last_seen[node] = time.monotonic()
                    except Exception:  # noqa: BLE001 — failure == missed beat
                        pass
                return cb

            fut.add_done_callback(made(n))
        for n in sorted(self.nodes - self.dead):
            if now - self.last_seen[n] > self.timeout:
                self.declare_dead(n)

    def declare_dead(self, node: int) -> None:
        if node in self.dead:
            return
        self.dead.add(node)
        if self.on_failure:
            self.on_failure(node)

    def run(self) -> None:
        while not self._stop.is_set():
            self._beat_once()
            self._stop.wait(self.interval)

    def start(self) -> "HeartbeatMonitor":
        self._thread = threading.Thread(target=self.run, daemon=True,
                                        name="ham-heartbeat")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)

    def alive(self) -> list[int]:
        return sorted(self.nodes - self.dead)


class StragglerDetector:
    """Flags nodes whose step time exceeds ``factor ×`` the fleet median."""

    def __init__(self, factor: float = 1.5, window: int = 16):
        self.factor = factor
        self.window = window
        self._times: dict[int, list[float]] = {}
        self._lock = threading.Lock()

    def record(self, node: int, dt: float) -> None:
        with self._lock:
            self._times.setdefault(node, []).append(dt)
            if len(self._times[node]) > self.window:
                self._times[node] = self._times[node][-self.window:]

    def _node_avg(self, node: int) -> float:
        ts = self._times.get(node, [])
        return sum(ts) / len(ts) if ts else 0.0

    def stragglers(self) -> list[int]:
        with self._lock:
            avgs = {n: self._node_avg(n) for n in self._times if self._times[n]}
        if len(avgs) < 2:
            return []
        vals = sorted(avgs.values())
        median = vals[len(vals) // 2]
        if median <= 0:
            return []
        return sorted(n for n, t in avgs.items() if t > self.factor * median)


class ElasticFleet:
    """Deterministic shard (re)assignment over the surviving membership.

    Rescale cost is O(local sort): the HAM key map needs no renegotiation
    (paper §5.2 — sorted stable names), only the data shards move.
    """

    def __init__(self, domain, worker_nodes: list[int]):
        self.domain = domain
        self.members = sorted(worker_nodes)
        self.epoch = 0

    def shard_of(self, node: int) -> tuple[int, int]:
        """(shard_index, num_shards) for a member under current membership."""
        if node not in self.members:
            raise NodeDownError(f"node {node} not in fleet")
        return self.members.index(node), len(self.members)

    def remove(self, node: int) -> dict[int, tuple[int, int]]:
        """Drop a dead node; returns the new shard map (node -> shard)."""
        self.members = [n for n in self.members if n != node]
        self.epoch += 1
        return {n: self.shard_of(n) for n in self.members}

    def admit(self, node: int, peer_digest_hex: str) -> dict[int, tuple[int, int]]:
        """Join path: verify the candidate derives the same key map (the
        32-byte same-source check), then extend membership."""
        local = self.domain.registry.table.digest.hex()
        if peer_digest_hex != local:
            raise KeyMapMismatchError(
                f"node {node} key-map digest {peer_digest_hex[:12]}… != "
                f"fleet {local[:12]}…"
            )
        if node not in self.members:
            self.members = sorted(self.members + [node])
            self.epoch += 1
        return {n: self.shard_of(n) for n in self.members}
