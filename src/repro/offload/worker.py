"""Worker-process bootstrap: run one HAM node in its own process.

Two launch modes:

* :func:`spawn_shm_workers` — fork children attached to a
  :class:`~repro.comm.shm.ShmFabric` (intra-node, SCIF/DMA analogue).
* ``python -m repro.offload.worker '<json-spec>'`` — a *fresh interpreter*
  (different process image => the "heterogeneous binaries" case) attaching
  over TCP.  The spec names the modules that register user handlers; the
  worker imports them (static initialisation), calls ``ham.init()``, checks
  nothing about the peer — agreement is guaranteed by the deterministic key
  map, and *verified* via the digest ping.

Both modes end when the host sends ``_ham/terminate``.
"""

from __future__ import annotations

import importlib
import json
import multiprocessing
import sys

from repro.core.registry import default_registry


def _worker_body(kind: str, args: dict, node_id: int, setup_modules: list[str]) -> None:
    for mod in setup_modules:
        importlib.import_module(mod)
    table = default_registry().init()
    if kind == "shm":
        from repro.comm.shm import ShmEndpoint

        endpoint = ShmEndpoint(args["prefix"], node_id, args["num_nodes"])
    elif kind == "socket":
        from repro.comm.socket import SocketEndpoint

        endpoint = SocketEndpoint(
            node_id, args["num_nodes"], args["base_port"], args.get("host", "127.0.0.1")
        )
    else:
        raise ValueError(f"unknown fabric kind {kind!r}")

    from repro.offload.runtime import NodeRuntime

    runtime = NodeRuntime(node_id, endpoint, table)
    runtime.run()
    endpoint.close()


def spawn_shm_workers(fabric, node_ids, setup_modules=()) -> list:
    """Fork one child per worker node, attached to ``fabric`` (ShmFabric)."""
    ctx = multiprocessing.get_context("fork")
    procs = []
    for node_id in node_ids:
        p = ctx.Process(
            target=_worker_body,
            args=(
                "shm",
                {"prefix": fabric.prefix, "num_nodes": fabric.num_nodes},
                node_id,
                list(setup_modules),
            ),
            daemon=True,
        )
        p.start()
        procs.append(p)
    return procs


def spawn_socket_worker_subprocess(
    node_id: int, num_nodes: int, base_port: int, setup_modules=()
):
    """Launch a worker as a *fresh* interpreter over TCP (subprocess)."""
    import os
    import subprocess

    spec = {
        "kind": "socket",
        "args": {"num_nodes": num_nodes, "base_port": base_port},
        "node_id": node_id,
        "setup_modules": list(setup_modules),
    }
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    env["PYTHONPATH"] = root + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.Popen(
        [sys.executable, "-m", "repro.offload.worker", json.dumps(spec)], env=env
    )


def main(argv: list[str]) -> int:
    spec = json.loads(argv[0])
    _worker_body(spec["kind"], spec["args"], spec["node_id"], spec["setup_modules"])
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
