"""Worker-process bootstrap: run one HAM node in its own process.

Two launch modes:

* :func:`spawn_shm_workers` — fork children attached to a
  :class:`~repro.comm.shm.ShmFabric` (intra-node, SCIF/DMA analogue).
* ``python -m repro.offload.worker '<json-spec>'`` — a *fresh interpreter*
  (different process image => the "heterogeneous binaries" case) attaching
  over TCP.  The spec names the modules that register user handlers; the
  worker imports them (static initialisation), calls ``ham.init()``, checks
  nothing about the peer — agreement is guaranteed by the deterministic key
  map, and *verified* via the digest ping.

Both modes end when the host sends ``_ham/terminate``.
"""

from __future__ import annotations

import importlib
import json
import multiprocessing
import sys

from repro.core.registry import default_registry


def registered_setup_modules(registry=None, extra=()) -> list[str]:
    """Modules whose import (re-)registers the host's handler set.

    A worker must import the SAME registering modules as the host before
    ``init()``, or the two processes derive different key maps — the
    paper's same-source assumption.  This derives that module list from
    the registry itself (every pending handler's defining module), so a
    host that imported, say, ``repro.cluster.pool`` (which registers
    ``_cluster/*`` at import) automatically ships it to its workers.
    ``__main__`` is dropped: script-local handlers cannot be re-imported
    by a fresh interpreter and must be registered via an importable module.
    """
    reg = registry or default_registry()
    mods = {r.fn.__module__ for r in reg.pending_records()}
    mods.update(extra)
    mods.discard("__main__")
    return sorted(m for m in mods if m)


def _worker_body(kind: str, args: dict, node_id: int, setup_modules: list[str]) -> None:
    for mod in setup_modules:
        importlib.import_module(mod)
    table = default_registry().init()
    if kind == "shm":
        from repro.comm.shm import RingConfig, ShmEndpoint

        endpoint = ShmEndpoint(args["prefix"], node_id, args["num_nodes"],
                               peers=args.get("peers"),
                               config=RingConfig.from_dict(args.get("ring")))
    elif kind == "socket":
        from repro.comm.socket import SocketEndpoint

        endpoint = SocketEndpoint(
            node_id, args["num_nodes"], args["base_port"], args.get("host", "127.0.0.1")
        )
    else:
        raise ValueError(f"unknown fabric kind {kind!r}")

    from repro.offload.runtime import NodeRuntime

    runtime = NodeRuntime(node_id, endpoint, table)
    # queue-depth feedback to the host (node 0); a no-op unless the handler
    # set includes _cluster/stats (i.e. the host runs a cluster scheduler)
    runtime.enable_depth_report(dst=0)
    try:
        runtime.run()
    finally:
        # a handler exception or interpreter teardown must still detach the
        # endpoint: on shm fabrics a child that exits without closing keeps
        # /dev/shm mappings referenced (the segment-leak path)
        endpoint.close()


def spawn_shm_workers(fabric, node_ids, setup_modules=None) -> list:
    """Fork one child per worker node, attached to ``fabric`` (ShmFabric).

    ``setup_modules=None`` (default) derives the worker's import list from
    the host's default registry via :func:`registered_setup_modules`, so
    both sides agree on the key map by construction.

    Segment-leak contract: the *fabric* owns the ``/dev/shm`` segments and
    unlinks them from ``ShmFabric.close`` (also registered ``atexit``), so a
    child dying mid-run cannot leak them; callers must still reap the
    children (``p.join``/``terminate`` — ``ClusterPool.close`` does both).
    """
    if setup_modules is None:
        setup_modules = registered_setup_modules()
    ctx = multiprocessing.get_context("fork")
    procs = []
    for node_id in node_ids:
        p = ctx.Process(
            target=_worker_body,
            args=(
                "shm",
                _shm_args(fabric),
                node_id,
                list(setup_modules),
            ),
            daemon=True,
        )
        p.start()
        procs.append(p)
    return procs


def _shm_args(fabric) -> dict:
    """Endpoint-construction args for a worker attaching to ``fabric``.
    ``peers`` carries the live member set — an elastic fabric may have holes
    (retired ids) whose segments no longer exist."""
    return {
        "prefix": fabric.prefix,
        "num_nodes": fabric.num_nodes,
        "peers": fabric.nodes(),
        # wakeup tunables travel with the spawn spec (JSON-serialisable) so
        # forked and fresh-interpreter workers honour the fabric's RingConfig
        "ring": fabric.config.as_dict(),
    }


def reap(procs, timeout: float = 5.0) -> None:
    """Join with escalation to terminate, then kill — children never outlive
    the pool (the other half of the segment-leak fix).  Accepts
    ``multiprocessing.Process`` and ``subprocess.Popen`` handles."""
    import subprocess

    for p in procs:
        if hasattr(p, "is_alive"):  # multiprocessing.Process
            p.join(timeout)
            if p.is_alive():
                p.terminate()
                p.join(1.0)
            if p.is_alive():
                p.kill()
                p.join(1.0)
        else:  # subprocess.Popen
            try:
                p.wait(timeout)
            except subprocess.TimeoutExpired:
                p.terminate()
                try:
                    p.wait(1.0)
                except subprocess.TimeoutExpired:
                    p.kill()
                    p.wait(1.0)


def _spawn_worker_subprocess(spec: dict):
    import os
    import subprocess

    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    env["PYTHONPATH"] = root + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.Popen(
        [sys.executable, "-m", "repro.offload.worker", json.dumps(spec)], env=env
    )


def spawn_socket_worker_subprocess(
    node_id: int, num_nodes: int, base_port: int, setup_modules=None
):
    """Launch a worker as a *fresh* interpreter over TCP (subprocess).

    ``setup_modules=None`` derives the import list from the host's default
    registry (see :func:`registered_setup_modules`) — a fresh interpreter
    has no inherited state, so it must re-run the same static-init imports.
    """
    if setup_modules is None:
        setup_modules = registered_setup_modules()
    return _spawn_worker_subprocess({
        "kind": "socket",
        "args": {"num_nodes": num_nodes, "base_port": base_port},
        "node_id": node_id,
        "setup_modules": list(setup_modules),
    })


def spawn_shm_worker_subprocess(fabric, node_id: int, setup_modules=None):
    """Launch a worker as a *fresh* interpreter attached to a ShmFabric.

    Same wire/segment behaviour as :func:`spawn_shm_workers`, but with no
    ``os.fork`` — required once the parent has started threads that cannot
    survive forking (a JAX-initialised test process is the canonical case).
    """
    if setup_modules is None:
        setup_modules = registered_setup_modules()
    return _spawn_worker_subprocess({
        "kind": "shm",
        "args": _shm_args(fabric),
        "node_id": node_id,
        "setup_modules": list(setup_modules),
    })


def main(argv: list[str]) -> int:
    spec = json.loads(argv[0])
    _worker_body(spec["kind"], spec["args"], spec["node_id"], spec["setup_modules"])
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
