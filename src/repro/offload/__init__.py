"""HAM-Offload: the offloading framework built on the HAM core (paper §2)."""

from repro.core.future import as_completed, gather
from repro.offload.api import OffloadDomain, deref, offloaded
from repro.offload.buffer import BufferPtr, BufferRegistry
from repro.offload.dataplane import BufferDirectory, register_dataplane_handlers
from repro.offload.runtime import NodeRuntime, current_node, register_internal_handlers

__all__ = [
    "OffloadDomain", "deref", "offloaded",
    "BufferPtr", "BufferRegistry", "BufferDirectory",
    "NodeRuntime", "current_node", "register_internal_handlers",
    "register_dataplane_handlers",
    "as_completed", "gather",
]
