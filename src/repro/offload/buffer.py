"""PGAS-style smart pointers (paper §2: ``buffer_ptr<T>``; §6: "smart
pointers that combine an address space or process identifier with a local
pointer").

A :class:`BufferPtr` is (node, handle, nbytes): 24 bytes on the wire,
registered as a fixed-size ``migratable`` so it can ride the *static* fast
path inside offloaded closures — exactly like the paper's bitwise-copyable
``buffer_ptr`` arguments in Fig. 2.  ``nbytes`` records the buffer's extent
at its owner, which is what lets locality-aware scheduling weigh votes by
the data actually behind a pointer instead of by pointer count (a pointer
of unknown provenance carries ``nbytes=0`` and votes with weight 1).

The per-node :class:`BufferRegistry` maps handles to live numpy arrays; only
the owning node may dereference (pointers are "in general only valid within
their original process's address space", §4.1 — here that rule is enforced).
"""

from __future__ import annotations

import dataclasses
import struct
import threading

import numpy as np

from repro.core.errors import OffloadError
from repro.core.migratable import register_migratable

_WIRE = struct.Struct("<qqq")


@dataclasses.dataclass(frozen=True)
class BufferPtr:
    node: int
    handle: int
    nbytes: int = 0  # buffer extent at the owner; 0 = unknown

    def encode(self) -> bytes:
        return _WIRE.pack(self.node, self.handle, self.nbytes)

    @staticmethod
    def decode(raw: bytes) -> "BufferPtr":
        node, handle, nbytes = _WIRE.unpack(raw)
        return BufferPtr(node, handle, nbytes)


register_migratable(
    BufferPtr,
    encode=lambda p: p.encode(),
    decode=BufferPtr.decode,
    type_name="ham:buffer_ptr",
    nbytes_fixed=_WIRE.size,
    # a buffer_ptr knows its address space: locality-aware scheduling routes
    # calls to the node already holding their buffers, weighted by how much
    # data sits behind the pointer
    locality=lambda p: p.node,
    locality_nbytes=lambda p: p.nbytes,
)


class BufferRegistry:
    """Handle -> array map of one node (the target side of allocate/put/get)."""

    def __init__(self, node_id: int):
        self.node_id = node_id
        self._lock = threading.Lock()
        self._buffers: dict[int, np.ndarray] = {}
        self._next = 1

    def allocate(self, shape, dtype) -> BufferPtr:
        arr = np.zeros(tuple(int(d) for d in shape), dtype=np.dtype(str(dtype)))
        with self._lock:
            handle = self._next
            self._next += 1
            self._buffers[handle] = arr
        return BufferPtr(self.node_id, handle, arr.nbytes)

    def deref(self, ptr: BufferPtr) -> np.ndarray:
        if ptr.node != self.node_id:
            raise OffloadError(
                f"dereferencing remote pointer (node {ptr.node}) on node "
                f"{self.node_id}: pointers are only valid in their own "
                "address space (paper §4.1)"
            )
        with self._lock:
            arr = self._buffers.get(ptr.handle)
        if arr is None:
            raise OffloadError(f"dangling buffer handle {ptr.handle}")
        return arr

    def flat(self, ptr: BufferPtr) -> np.ndarray:
        """1-D zero-copy view of a buffer — the put/get data plane addresses
        buffers by flat element offset (chunked transfers slice this view)."""
        return self.deref(ptr).reshape(-1)

    def free(self, ptr: BufferPtr) -> None:
        with self._lock:
            if self._buffers.pop(ptr.handle, None) is None:
                raise OffloadError(f"double free of handle {ptr.handle}")

    def live_count(self) -> int:
        with self._lock:
            return len(self._buffers)
