"""PGAS-style smart pointers (paper §2: ``buffer_ptr<T>``; §6: "smart
pointers that combine an address space or process identifier with a local
pointer").

A :class:`BufferPtr` is (node, handle, nbytes, epoch): 32 bytes on the wire,
registered as a fixed-size ``migratable`` so it can ride the *static* fast
path inside offloaded closures — exactly like the paper's bitwise-copyable
``buffer_ptr`` arguments in Fig. 2.

Location transparency (the post-HAM refactor; cf. HPX's AGAS decoupling
object identity from placement):

* ``handle`` is a **stable global id** — unique cluster-wide (allocating
  nodes namespace their counters by node id), and preserved when the buffer
  is replicated or migrated.  The handle *is* the buffer's identity; the
  ``node`` field is only a **placement hint**: where the primary copy lived
  when this pointer was minted.
* ``epoch`` is the **ownership epoch** the hint was minted under.  Every
  time the primary moves (replica promotion on crash, drain migration on
  shrink) the :class:`~repro.offload.dataplane.BufferDirectory` bumps the
  buffer's epoch — so a pointer whose epoch is older than the directory's
  is *stale* and gets transparently re-resolved (hint rewritten) instead of
  erroring, while an up-to-date pointer skips the directory entirely.
* ``nbytes`` records the buffer's extent, which lets locality-aware
  scheduling weigh votes by the data actually behind a pointer (a pointer
  of unknown provenance carries ``nbytes=0`` and votes with weight 1).

The per-node :class:`BufferRegistry` maps handles to live numpy arrays; only
a node actually *holding* a copy may dereference (pointers are "in general
only valid within their original process's address space", §4.1 — here the
rule is enforced per copy: a replica holder adopts the buffer under the
same global handle, so a pointer retargeted at it dereferences fine).
"""

from __future__ import annotations

import dataclasses
import struct
import threading

import numpy as np

from repro.core.errors import OffloadError
from repro.core.migratable import register_migratable

_WIRE = struct.Struct("<qqqq")

#: global handles are ``(node_id << _HANDLE_SHIFT) | local_counter`` — every
#: node mints ids no other node can mint, so a replica can be installed
#: under its primary's handle without ever clashing with the holder's own
#: allocations (the precondition for a location-transparent namespace)
_HANDLE_SHIFT = 48


def handle_minter(handle: int) -> int:
    """Node that minted ``handle`` (NOT necessarily the current owner)."""
    return handle >> _HANDLE_SHIFT


@dataclasses.dataclass(frozen=True)
class BufferPtr:
    node: int        # placement hint: primary holder as of `epoch`
    handle: int      # stable global id (identity; survives migration)
    nbytes: int = 0  # buffer extent at the owner; 0 = unknown
    epoch: int = 0   # ownership epoch the hint was minted under

    def encode(self) -> bytes:
        return _WIRE.pack(self.node, self.handle, self.nbytes, self.epoch)

    @staticmethod
    def decode(raw: bytes) -> "BufferPtr":
        node, handle, nbytes, epoch = _WIRE.unpack(raw)
        return BufferPtr(node, handle, nbytes, epoch)

    def at(self, node: int, epoch: int | None = None) -> "BufferPtr":
        """Same buffer, rewritten placement hint (directory resolution)."""
        return BufferPtr(node, self.handle, self.nbytes,
                         self.epoch if epoch is None else epoch)


register_migratable(
    BufferPtr,
    encode=lambda p: p.encode(),
    decode=BufferPtr.decode,
    type_name="ham:buffer_ptr",
    nbytes_fixed=_WIRE.size,
    # a buffer_ptr knows its address space: locality-aware scheduling routes
    # calls to the node already holding their buffers, weighted by how much
    # data sits behind the pointer.  With a BufferDirectory attached the
    # scheduler widens this single-node hint to EVERY live replica holder
    # (scan_locality's resolver hook) — any copy can serve a read.
    locality=lambda p: p.node,
    locality_nbytes=lambda p: p.nbytes,
)


class BufferRegistry:
    """Handle -> array map of one node (the target side of allocate/put/get).

    Handles minted here are globally unique (node-id-namespaced counters),
    and :meth:`adopt` installs a *foreign* buffer under its original handle
    — the two halves of replica/migration support.
    """

    def __init__(self, node_id: int):
        self.node_id = node_id
        self._lock = threading.Lock()
        self._buffers: dict[int, np.ndarray] = {}
        self._next = 1

    def allocate(self, shape, dtype) -> BufferPtr:
        arr = np.zeros(tuple(int(d) for d in shape), dtype=np.dtype(str(dtype)))
        with self._lock:
            handle = (self.node_id << _HANDLE_SHIFT) | self._next
            self._next += 1
            self._buffers[handle] = arr
        return BufferPtr(self.node_id, handle, arr.nbytes)

    def adopt(self, handle: int, arr: np.ndarray) -> None:
        """Install ``arr`` under an externally-minted global ``handle`` —
        the receiving half of replication/migration.  Idempotent for a
        same-shape re-adopt (a replica refresh overwrites in place)."""
        with self._lock:
            self._buffers[int(handle)] = arr

    def adopt_empty(self, handle: int, shape, dtype) -> np.ndarray:
        arr = np.zeros(tuple(int(d) for d in shape), dtype=np.dtype(str(dtype)))
        self.adopt(handle, arr)
        return arr

    def holds(self, handle: int) -> bool:
        with self._lock:
            return int(handle) in self._buffers

    def deref(self, ptr: BufferPtr) -> np.ndarray:
        if ptr.node != self.node_id:
            raise OffloadError(
                f"dereferencing remote pointer (node {ptr.node}) on node "
                f"{self.node_id}: pointers are only valid in their own "
                "address space (paper §4.1)"
            )
        with self._lock:
            arr = self._buffers.get(ptr.handle)
        if arr is None:
            raise OffloadError(f"dangling buffer handle {ptr.handle}")
        return arr

    def flat(self, ptr: BufferPtr) -> np.ndarray:
        """1-D zero-copy view of a buffer — the put/get data plane addresses
        buffers by flat element offset (chunked transfers slice this view)."""
        return self.deref(ptr).reshape(-1)

    def free(self, ptr: BufferPtr) -> None:
        with self._lock:
            if self._buffers.pop(ptr.handle, None) is None:
                raise OffloadError(f"double free of handle {ptr.handle}")

    def discard(self, handle: int) -> bool:
        """Replica invalidation: drop ``handle`` if held.  Idempotent (an
        invalidate may race a free — both outcomes are 'copy gone')."""
        with self._lock:
            return self._buffers.pop(int(handle), None) is not None

    def live_count(self) -> int:
        with self._lock:
            return len(self._buffers)
