"""Per-node active-message runtime: the "minimal runtime" of HAM-Offload.

One :class:`NodeRuntime` per process/thread-node:

* pulls frames from its comm endpoint,
* replies are routed to the sender's :class:`FutureTable` (the
  ``offload_result_msg`` path of paper Fig. 5),
* requests are executed through the node's :class:`ExecutionPolicy`; if the
  frame carries a ``msg_id`` the result is packed and sent back as a REPLY
  frame (errors as REPLY|ERROR with the remote traceback).

Hot path (the paper's Fig. 3 metric is this module's cost):

* the event loop drains frames in *batches* via ``recv_many`` — on
  zero-copy transports (shm rings) the frames are leased views into the
  receive window, decoded in place and only copied when something outlives
  the dispatch (a reply resolving a future, a non-direct execution policy);
* replies and oneway sends produced while draining a batch are parked in an
  egress queue and flushed as one coalesced ``send_many`` per destination —
  one transport publication per drain iteration instead of per message;
* frames are packed at their exact final size (header + measured payload)
  so multi-megabyte put/get payloads see a single copy into the frame.

Handlers receive argument views that alias the inbound frame.  On leased
transports those views die when the batch is released, so a handler that
*retains* a payload (stores an array, returns it by reference) must copy —
everything else rides the bitwise fast path copy-free.

Internal handlers (registered at import, i.e. "static initialisation", with
explicit names so they sort deterministically — cf. the paper's
``terminate_functor`` appearing in its Fig. 7 dump):

* ``_ham/alloc``, ``_ham/free``, ``_ham/put``, ``_ham/get`` — buffer plane
* ``_ham/ping`` — liveness/barrier
* ``_ham/forward`` — one-hop relay (offload-over-fabric routing)
* ``_ham/terminate`` — stops the event loop

Handlers executing on a node can access "their" node via
:func:`current_node` (contextvar set around execution) — this is how
offloaded user code dereferences :class:`BufferPtr` arguments and how
*reverse offload* (worker calling back into the host) gets a sender.
"""

from __future__ import annotations

import contextvars
import sys
import threading
import time
import traceback
from typing import Any

import numpy as np

from repro.comm.base import CommBackend
from repro.core import migratable as mig
from repro.core.closure import Function
from repro.core.errors import NodeDownError, OffloadError
from repro.core.future import Future, FutureTable
from repro.core.executor import DirectPolicy, ExecutionPolicy
from repro.core.message import (
    FLAG_DYNAMIC,
    FLAG_ERROR,
    FLAG_REPLY,
    HEADER_NBYTES,
    HEADER_STRUCT,
    MAGIC,
    VERSION,
    decode_fast,
)
from repro.core.migratable import static_payload_nbytes
from repro.core.registry import HandlerTable, default_registry
from repro.offload.buffer import BufferPtr, BufferRegistry

_current_node: contextvars.ContextVar["NodeRuntime | None"] = contextvars.ContextVar(
    "ham_current_node", default=None
)

_DRAIN_BATCH = 64  # frames pulled per recv_many in the event loop
_BIG_FRAME = 1 << 16  # above this, frames come from the pooled allocator


class _FramePool:
    """Refcount-checked reuse of large frame buffers.

    Freshly ``np.empty``-allocated multi-megabyte frames pay a page-fault
    storm on first touch (~40 us/MB); reusing warm buffers removes it.  A
    pooled buffer is handed out again only when *nothing outside the pool*
    references its backing array — transports drop their reference once the
    frame is delivered, while a reply frame pinned by a zero-copy result
    array stays referenced (and therefore un-reusable) until the caller
    drops the result.  The refcount check makes reuse safe without any
    explicit free protocol.
    """

    def __init__(self, max_items: int = 8):
        self._items: list[np.ndarray] = []
        self._max = max_items
        self._lock = threading.Lock()

    def take(self, nbytes: int) -> memoryview:
        with self._lock:
            # index-based scan: enumerate() would reuse its yield tuple and
            # keep a hidden extra reference to the candidate, breaking the
            # refcount test.  A free buffer is referenced exactly by the pool
            # list, the local `arr`, and getrefcount's argument => 3.
            for i in range(len(self._items)):
                arr = self._items[i]
                if arr.nbytes >= nbytes and sys.getrefcount(arr) == 3:
                    self._items.append(self._items.pop(i))  # LRU to the back
                    return memoryview(arr)[:nbytes]
        # round up so slightly-different frame sizes share buffers
        alloc = (nbytes + 0xFFFF) & ~0xFFFF
        arr = np.empty(alloc, dtype=np.uint8)
        with self._lock:
            self._items.append(arr)
            if len(self._items) > self._max:
                # evict the oldest *free* buffer (busy ones must stay tracked)
                for i in range(len(self._items)):
                    old = self._items[i]
                    if sys.getrefcount(old) == 3:
                        del self._items[i]
                        break
        return memoryview(arr)[:nbytes]


_frame_pool = _FramePool()


def _alloc_frame(nbytes: int):
    """Writable frame buffer of ``nbytes``.

    ``bytearray(n)`` zero-fills — a full extra memory pass on multi-megabyte
    put/get payloads that the packer immediately overwrites.  Large frames
    therefore come from the (uninitialised, refcount-pooled) numpy allocator,
    wrapped in a memoryview so every consumer sees a flat byte buffer; small
    frames stay bytearray (lower constant cost).
    """
    if nbytes >= _BIG_FRAME:
        return _frame_pool.take(nbytes)
    return bytearray(nbytes)


def current_node() -> "NodeRuntime":
    node = _current_node.get()
    if node is None:
        raise OffloadError("no HAM node runtime active in this context")
    return node


# --------------------------------------------------------------------------
# internal handlers (dynamic payloads; explicit stable names)
# --------------------------------------------------------------------------


def _h_alloc(shape, dtype):
    node = current_node()
    ptr = node.buffers.allocate(shape, dtype)
    return ("ptr", ptr.node, ptr.handle, ptr.nbytes)


def _h_free(node_id, handle):
    current_node().buffers.free(BufferPtr(node_id, handle))
    return None


def _h_put(node_id, handle, offset, array):
    # `array` may alias the inbound frame (zero-copy unpack); the slice
    # assignment below is the single payload copy of the put path
    flat = current_node().buffers.flat(BufferPtr(node_id, handle))
    n = array.size
    flat[offset : offset + n] = array.reshape(-1).astype(flat.dtype, copy=False)
    return None


def _h_get(node_id, handle, offset, count):
    node = current_node()
    # return VIEWS: the reply is packed (= copied) before this handler's
    # dispatch ends, so the get path pays exactly one payload copy
    if count < 0 and not offset:
        return node.buffers.deref(BufferPtr(node_id, handle))  # keeps shape
    flat = node.buffers.flat(BufferPtr(node_id, handle))
    if count < 0:
        return flat[offset:]
    return flat[offset : offset + count]


def _h_ping(token):
    return token


def _h_forward(dst, frame_bytes):
    """Relay an embedded frame one hop (offload over fabric).  The final
    target replies straight to the origin recorded in the inner header."""
    node = current_node()
    node._send_frame(dst, frame_bytes)
    return None


def _h_terminate():
    current_node().request_stop()
    return None


def register_internal_handlers(registry=None) -> None:
    reg = registry or default_registry()
    for name, fn in (
        ("_ham/alloc", _h_alloc),
        ("_ham/free", _h_free),
        ("_ham/put", _h_put),
        ("_ham/get", _h_get),
        ("_ham/ping", _h_ping),
        ("_ham/forward", _h_forward),
        ("_ham/terminate", _h_terminate),
    ):
        reg.register(fn, name=name)


# module import = static initialisation (paper §4.3)
register_internal_handlers()


# --------------------------------------------------------------------------
# the runtime
# --------------------------------------------------------------------------


class NodeRuntime:
    def __init__(
        self,
        node_id: int,
        endpoint: CommBackend,
        table: HandlerTable,
        policy: ExecutionPolicy | None = None,
        *,
        inline: bool = False,
    ):
        self.node_id = node_id
        self.endpoint = endpoint
        self.table = table
        self.policy = policy or DirectPolicy()
        self.buffers = BufferRegistry(node_id)
        self.futures = FutureTable()
        self.inline = inline
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._sync_seq = 0  # inline futureless-sync sequence counter
        # egress coalescing: replies/oneways emitted while the event-loop
        # thread drains a batch are grouped into one send_many per dst
        self._egress: list[tuple[int, Any]] = []
        self._draining = False
        self._loop_tid: int | None = None
        self.stats = {"handled": 0, "replies": 0, "errors": 0, "sent": 0,
                      "batches": 0}
        # -- queue-depth feedback (scheduler's remote-load signal) ---------
        #: last depth reported BY each peer via _cluster/stats oneways
        #: (populated on the node peers report to — normally the host)
        self.peer_depth: dict[int, int] = {}
        self._depth_dst: int | None = None       # report target (None = off)
        self._depth_interval = 0.05
        self._depth_record = None                # _cluster/stats HandlerRecord
        self._depth_last_sent = 0
        self._depth_last_t = 0.0
        self._batch_remaining = 0                # frames left in current drain

    # -- queue-depth feedback ----------------------------------------------

    def enable_depth_report(self, dst: int = 0,
                            interval: float = 0.05) -> "NodeRuntime":
        """Report this node's queue depth to ``dst`` (normally the host) as
        ``_cluster/stats`` oneways — at most one per ``interval`` while busy,
        plus an immediate zero report when the queue drains, so the receiver
        never acts on a stale busy signal.  Silently disabled when the
        handler table has no ``_cluster/stats`` entry (non-cluster domains).
        """
        try:
            self._depth_record = self.table.record_of("_cluster/stats")
        except Exception:  # noqa: BLE001 — UnknownHandlerError et al.
            self._depth_record = None
            return self
        self._depth_dst = dst
        self._depth_interval = interval
        return self

    def note_peer_depth(self, node_id: int, depth: int) -> None:
        """Receiver side of the depth protocol (called by _cluster/stats)."""
        self.peer_depth[int(node_id)] = int(depth)

    def queue_depth(self) -> int:
        """Requests this node has accepted but not finished executing: the
        rest of the current drain batch plus what the transport has queued.
        The remote half of the scheduler's join-shortest-queue signal."""
        try:
            pending = self.endpoint.pending_frames()
        except Exception:  # noqa: BLE001 — estimate only, never fail dispatch
            pending = 0
        return self._batch_remaining + pending

    def _maybe_report_depth(self, force_zero: bool = False) -> None:
        """Emit a depth report if one is due.  Sends bypass the egress queue
        (a depth report parked behind the batch it describes is useless)."""
        if self._depth_dst is None:
            return
        now = time.monotonic()
        if not force_zero and now - self._depth_last_t < self._depth_interval:
            # rate limit busy reports — and skip the depth walk entirely
            # between ticks (this runs per frame on the hot path); the
            # busy->idle edge is caught by the force_zero call from the
            # loop's idle branch, which bypasses the limit
            return
        depth = 0 if force_zero else self.queue_depth()
        if depth == self._depth_last_sent:
            return
        record = self._depth_record
        args = (self.node_id, depth)
        n = mig.dynamic_nbytes(list(args))
        frame = bytearray(HEADER_NBYTES + n)
        mig.pack_dynamic_into(frame, HEADER_NBYTES, list(args))
        HEADER_STRUCT.pack_into(frame, 0, MAGIC, VERSION, FLAG_DYNAMIC,
                                self.table.key_of(record.stable_name),
                                self.node_id, 0, n)
        try:
            self.endpoint.send(self._depth_dst, frame)
        except Exception:  # noqa: BLE001 — advisory traffic must never kill
            # the loop (e.g. the host endpoint is tearing down)
            return
        self._depth_last_sent = depth
        self._depth_last_t = now

    # -- sending ------------------------------------------------------------

    def send_async(self, dst: int, function: Function) -> Future:
        msg_id, fut = self.futures.create()
        self._send_request(dst, function, msg_id)
        return fut

    def send_oneway(self, dst: int, function: Function) -> None:
        """Fire-and-forget (msg_id 0 => no reply)."""
        self._send_request(dst, function, 0)

    def _send_frame(self, dst: int, frame) -> None:
        """Transport egress: coalesced while the loop thread drains a batch,
        immediate otherwise (user threads never see queueing)."""
        cap = getattr(self.endpoint, "max_frame_nbytes", None)
        if cap is not None and len(frame) > cap:
            # fail fast, HERE: parking an oversized frame in the egress queue
            # would defer the error past the handler's error-reply wrapping
            from repro.core.errors import CommError

            raise CommError(
                f"frame of {len(frame)} bytes exceeds transport frame "
                f"capacity {cap}"
            )
        if self._draining and threading.get_ident() == self._loop_tid:
            self._egress.append((dst, frame))
        else:
            self.endpoint.send(dst, frame)

    def _flush_egress(self) -> None:
        if not self._egress or threading.get_ident() != self._loop_tid:
            return
        egress, self._egress = self._egress, []
        if len(egress) == 1:
            dst, frame = egress[0]
            self.endpoint.send(dst, frame)
            return
        by_dst: dict[int, list] = {}
        for dst, frame in egress:
            by_dst.setdefault(dst, []).append(frame)
        for dst, frames in by_dst.items():
            self.endpoint.send_many(dst, frames)

    def _send_request(self, dst: int, function: Function, msg_id: int) -> None:
        # zero-extra-copy frame assembly: the frame is allocated at its exact
        # final size and the payload packed straight in after the 32-byte
        # header (the bitwise fast path; no bytearray growth reallocs)
        record = function.record
        key = self.table.key_of(record.stable_name)
        if record.is_static:
            n = static_payload_nbytes(record.arg_specs)
            frame = bytearray(HEADER_NBYTES + n)
            mig.pack_static(function.args, record.arg_specs,
                            out=memoryview(frame)[HEADER_NBYTES:])
            flags = 0
        else:
            args = list(function.args)
            n = mig.dynamic_nbytes(args)
            frame = _alloc_frame(HEADER_NBYTES + n)
            mig.pack_dynamic_into(frame, HEADER_NBYTES, args)
            flags = FLAG_DYNAMIC
        HEADER_STRUCT.pack_into(frame, 0, MAGIC, VERSION, flags, key,
                                self.node_id, msg_id, n)
        self._send_frame(dst, frame)
        self.stats["sent"] += 1

    def send_sync(self, dst: int, function: Function, timeout: float | None = 30.0):
        if self.inline:
            return self._send_sync_inline(dst, function, timeout)
        fut = self.send_async(dst, function)
        return fut.get(timeout)

    def _send_sync_inline(self, dst: int, function: Function,
                          timeout: float | None):
        """Futureless fast path (the Fig. 3 configuration): the caller
        thread polls its endpoint for the reply — no Future allocation, no
        Event wakeup, no table lock.  Interleaved requests still execute."""
        self._sync_seq += 1
        msg_id = 0x8000_0000_0000_0000 | self._sync_seq
        self._send_request(dst, function, msg_id)
        recv = self.endpoint.recv
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            frame = recv(timeout=0.1)
            if frame is None:
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError("inline sync offload timed out")
                continue
            key, flags, src, mid, payload = decode_fast(frame)
            if flags & FLAG_REPLY and mid == msg_id:
                if flags & FLAG_ERROR:
                    err = mig.unpack_dynamic(payload)
                    from repro.core.errors import RemoteExecutionError

                    raise RemoteExecutionError(err["msg"], err.get("tb", ""))
                return mig.unpack_dynamic(payload)
            self._handle_frame(frame)

    def _inline_wait(self, fut: Future, timeout: float | None):
        """Caller-thread polling: the lowest-latency mode (no wakeup hop).
        Interleaved inbound requests are still served, so reverse offload
        works even in inline mode."""
        # a handler waiting mid-batch must not deadlock on its own parked
        # egress (e.g. a request it just sent): push it out before blocking
        self._flush_egress()
        deadline = None if timeout is None else time.monotonic() + timeout
        while not fut.done():
            frame = self.endpoint.recv(timeout=0.1)
            if frame is not None:
                self._handle_frame(frame)
                self._flush_egress()
            elif deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("inline sync offload timed out")
        return fut.get(0)

    def wait(self, fut: Future, timeout: float | None = 30.0):
        """Cooperatively wait on a future *from handler context*.

        With the Direct execution policy the handler runs on the event-loop
        thread; plain ``fut.get()`` there would deadlock (the loop cannot pump
        the reply).  ``wait`` keeps servicing inbound frames while blocked —
        the cooperative-runtime pattern the paper's execution policies enable.
        With a thread-pool policy, plain ``fut.get()`` is also fine.
        """
        return self._inline_wait(fut, timeout)

    # -- receiving ------------------------------------------------------------

    def _handle_frame(self, frame, owned: bool = True) -> None:
        # hot path: the paper's metric is exactly this function's cost.
        # ``owned=False`` marks a leased transport view: anything escaping
        # this call (futures, deferred execution) must copy first.
        key, flags, src, msg_id, payload = decode_fast(frame)
        if flags & FLAG_REPLY:
            self.stats["replies"] += 1
            if not owned:
                payload = bytes(payload)  # escapes into the future table
            if flags & FLAG_ERROR:
                err = mig.unpack_dynamic(payload)
                self.futures.reject(msg_id, err["msg"], err.get("tb", ""))
            else:
                self.futures.resolve(msg_id, mig.unpack_dynamic(payload))
            return
        record = self.table.handler_at(key)
        if type(self.policy) is DirectPolicy:  # skip the closure on the hot path
            # executes before the lease is released — views are safe in place
            self._execute(record, key, src, msg_id, payload)
        else:
            if not owned:
                payload = bytes(payload)  # outlives the drain iteration
            self.policy.submit(lambda: self._execute(record, key, src, msg_id,
                                                     payload))

    def _execute(self, record, key, src, msg_id, payload) -> None:
        token = _current_node.set(self)  # policy may run on a pool thread
        try:
            self.stats["handled"] += 1
            try:
                args = Function.unpack_args(record, payload)
                result = record.fn(*args)
            except Exception as e:  # noqa: BLE001 — remote errors must travel
                self.stats["errors"] += 1
                if msg_id:
                    self._send_reply(src, key, msg_id,
                                     {"msg": f"{type(e).__name__}: {e}",
                                      "tb": traceback.format_exc()},
                                     FLAG_REPLY | FLAG_ERROR)
                return
            if msg_id:
                try:
                    self._send_reply(src, key, msg_id, result, FLAG_REPLY)
                except Exception as e:  # noqa: BLE001 — e.g. reply exceeds the
                    # transport frame limit: the caller must get an error, not
                    # a dead worker and a timeout
                    self.stats["errors"] += 1
                    self._send_reply(
                        src, key, msg_id,
                        {"msg": f"{type(e).__name__}: {e}",
                         "tb": traceback.format_exc()},
                        FLAG_REPLY | FLAG_ERROR,
                    )
        finally:
            _current_node.reset(token)

    def _send_reply(self, dst: int, key: int, msg_id: int, result, flags) -> None:
        n = mig.dynamic_nbytes(result)
        frame = _alloc_frame(HEADER_NBYTES + n)
        mig.pack_dynamic_into(frame, HEADER_NBYTES, result)
        HEADER_STRUCT.pack_into(frame, 0, MAGIC, VERSION, flags,
                                key, self.node_id, msg_id, n)
        self._send_frame(dst, frame)

    # -- event loop -----------------------------------------------------------

    def run(self, poll_timeout: float = 0.1) -> None:
        """Batch-drain event loop: pull up to ``_DRAIN_BATCH`` frames per
        ``recv_many``, dispatch them (decoding in place from leased views on
        zero-copy transports), release the lease, then flush the coalesced
        egress — one transport publication per drain iteration."""
        ep = self.endpoint
        leased = getattr(ep, "zero_copy_recv", False)
        self._loop_tid = threading.get_ident()
        while not self._stop.is_set():
            frames = ep.recv_many(_DRAIN_BATCH, timeout=poll_timeout)
            if not frames:
                # idle: retract any stale busy signal so the scheduler does
                # not keep routing around a worker that already drained
                self._maybe_report_depth(force_zero=True)
                continue
            self.stats["batches"] += 1
            self._draining = True
            self._batch_remaining = len(frames)
            try:
                for frame in frames:
                    # report BEFORE executing: a long handler must not hide
                    # the queue that is forming behind it
                    self._maybe_report_depth()
                    try:
                        self._handle_frame(frame, owned=not leased)
                    except Exception:  # noqa: BLE001 — a poison frame must
                        # not kill the event loop (remaining frames, futures
                        # and peers all depend on it staying alive)
                        self.stats["errors"] += 1
                        traceback.print_exc()
                    self._batch_remaining -= 1
            finally:
                self._draining = False
                self._batch_remaining = 0
                # drop frame refs BEFORE blocking in the next recv_many:
                # holding them would pin pooled frame buffers (and leased
                # ring space) across the idle wait
                frame = frames = None
                ep.release()  # return window space before the egress flush
                try:
                    self._flush_egress()
                except Exception:  # noqa: BLE001 — a failed send must not
                    # take down the loop; peers/futures depend on it
                    self.stats["errors"] += 1
                    traceback.print_exc()

    def start(self) -> "NodeRuntime":
        if self.inline:
            raise OffloadError("inline runtimes poll from the caller thread")
        self._thread = threading.Thread(
            target=self.run, name=f"ham-node-{self.node_id}", daemon=True
        )
        self._thread.start()
        return self

    def request_stop(self) -> None:
        self._stop.set()

    def stop(self, timeout: float = 5.0) -> None:
        self.request_stop()
        if self._thread is not None:
            self._thread.join(timeout)
        n = self.futures.fail_all(NodeDownError(f"node {self.node_id} stopped"))
        if n:
            self.stats["errors"] += n
