"""Per-node active-message runtime: the "minimal runtime" of HAM-Offload.

One :class:`NodeRuntime` per process/thread-node:

* pulls frames from its comm endpoint,
* replies are routed to the sender's :class:`FutureTable` (the
  ``offload_result_msg`` path of paper Fig. 5),
* requests are executed through the node's :class:`ExecutionPolicy`; if the
  frame carries a ``msg_id`` the result is packed and sent back as a REPLY
  frame (errors as REPLY|ERROR with the remote traceback).

Hot path (the paper's Fig. 3 metric is this module's cost):

* the event loop drains frames in *batches* via ``recv_many`` — on
  zero-copy transports (shm rings) the frames are leased views into the
  receive window, decoded in place and only copied when something outlives
  the dispatch (a reply resolving a future, a non-direct execution policy);
* dispatch drives off **dense key-indexed plan arrays** compiled at
  ``HandlerTable`` init (``repro.core.wireplan``): a static-spec handler's
  request is packed by its precompiled :class:`WirePlan` into a
  ``FLAG_STATIC`` frame (one fused struct call for scalar leaves, fixed
  extents for arrays) and its result travels back as a plan-packed
  ``FLAG_STATIC`` reply — no TLV, no per-message spec walk, no
  ``HandlerRecord`` attribute chasing; dynamic TLV stays as the fallback
  for ``arg_specs=None`` handlers, selected per frame via the header bits;
* **small-call fusion** (``FLAG_FUSED``): sub-threshold same-destination
  frames produced while draining a batch are folded into one multi-call
  frame (see ``core/message.py`` for the segment layout), and
  :meth:`send_fused` packs a caller-side batch the same way — one header,
  one transport publication, one dispatch pass for N calls, with replies
  fused symmetrically on the way back;
* replies and oneway sends produced while draining a batch are parked in an
  egress queue and flushed as one coalesced ``send_many`` per destination —
  one transport publication per drain iteration instead of per message;
* frames are packed at their exact final size (header + measured payload)
  so multi-megabyte put/get payloads see a single copy into the frame.

Handlers receive argument views that alias the inbound frame.  On leased
transports those views die when the batch is released, so a handler that
*retains* a payload (stores an array, returns it by reference) must copy —
everything else rides the bitwise fast path copy-free.

Internal handlers (registered at import, i.e. "static initialisation", with
explicit names so they sort deterministically — cf. the paper's
``terminate_functor`` appearing in its Fig. 7 dump):

* ``_ham/alloc``, ``_ham/free``, ``_ham/put``, ``_ham/get`` — buffer plane
* ``_ham/ping`` — liveness/barrier
* ``_ham/forward`` — one-hop relay (offload-over-fabric routing)
* ``_ham/terminate`` — stops the event loop

Handlers executing on a node can access "their" node via
:func:`current_node` (contextvar set around execution) — this is how
offloaded user code dereferences :class:`BufferPtr` arguments and how
*reverse offload* (worker calling back into the host) gets a sender.
"""

from __future__ import annotations

import contextvars
import os
import sys
import threading
import time
import traceback
from typing import Any

import numpy as np

from repro.comm.base import CommBackend
from repro.core import migratable as mig
from repro.core.closure import Function
from repro.core.errors import MessageFormatError, NodeDownError, OffloadError
from repro.core.flags import MSG_ID_FLUSH
from repro.core.future import Future, FutureTable
from repro.core.executor import DirectPolicy, ExecutionPolicy
from repro.core.message import (
    FLAG_DYNAMIC,
    FLAG_ERROR,
    FLAG_FUSED,
    FLAG_REPLY,
    FLAG_RETRYABLE,
    FLAG_SEG_SRC,
    FLAG_SHAPED,
    FLAG_STATIC,
    FUSED_COUNT_STRUCT,
    HEADER_NBYTES,
    HEADER_STRUCT,
    MAGIC,
    SEG_NBYTES,
    SEG_SRC_NBYTES,
    SEG_SRC_STRUCT,
    SEG_STRUCT,
    VERSION,
    decode_fast,
    iter_fused,
)
from repro.core.registry import HandlerTable, default_registry
from repro.core.wireplan import SIG_LEN_NBYTES, SIG_LEN_STRUCT, ShapeCache
from repro.offload.buffer import BufferPtr, BufferRegistry

_current_node: contextvars.ContextVar["NodeRuntime | None"] = contextvars.ContextVar(
    "ham_current_node", default=None
)

_DRAIN_BATCH = 64  # frames pulled per recv_many in the event loop
_BIG_FRAME = 1 << 16  # above this, frames come from the pooled allocator

#: small-call fusion: frames with payloads at or below this fold into one
#: FLAG_FUSED frame when they share a destination (the ≤256 B static-args
#: regime of the Fig. 3 claim, with headroom for small dynamic replies)
FUSE_THRESHOLD = 512
#: segments per fused frame — bounds decode scratch and keeps one poison
#: batch from dominating a drain iteration
FUSE_MAX_SEGMENTS = 64


class _FramePool:
    """Refcount-checked reuse of large frame buffers.

    Freshly ``np.empty``-allocated multi-megabyte frames pay a page-fault
    storm on first touch (~40 us/MB); reusing warm buffers removes it.  A
    pooled buffer is handed out again only when *nothing outside the pool*
    references its backing array — transports drop their reference once the
    frame is delivered, while a reply frame pinned by a zero-copy result
    array stays referenced (and therefore un-reusable) until the caller
    drops the result.  The refcount check makes reuse safe without any
    explicit free protocol.
    """

    def __init__(self, max_items: int = 8):
        self._items: list[np.ndarray] = []
        self._max = max_items
        self._lock = threading.Lock()

    def take(self, nbytes: int) -> memoryview:
        with self._lock:
            # index-based scan: enumerate() would reuse its yield tuple and
            # keep a hidden extra reference to the candidate, breaking the
            # refcount test.  A free buffer is referenced exactly by the pool
            # list, the local `arr`, and getrefcount's argument => 3.
            for i in range(len(self._items)):
                arr = self._items[i]
                if arr.nbytes >= nbytes and sys.getrefcount(arr) == 3:
                    self._items.append(self._items.pop(i))  # LRU to the back
                    return memoryview(arr)[:nbytes]
        # round up so slightly-different frame sizes share buffers
        alloc = (nbytes + 0xFFFF) & ~0xFFFF
        arr = np.empty(alloc, dtype=np.uint8)
        with self._lock:
            self._items.append(arr)
            if len(self._items) > self._max:
                # evict the oldest *free* buffer (busy ones must stay tracked)
                for i in range(len(self._items)):
                    old = self._items[i]
                    if sys.getrefcount(old) == 3:
                        del self._items[i]
                        break
        return memoryview(arr)[:nbytes]


_frame_pool = _FramePool()


class ReplayCache:
    """Exactly-once dedup for retransmitted requests (docs/failure-model.md).

    Keyed by ``(src_node, msg_id)`` — msg_ids are per-sender monotonic, so
    the pair names one logical call forever.  Entries move through three
    states: *in progress* (first arrival is executing — a duplicate arriving
    mid-execution on a pooled policy is dropped, the original will reply),
    *cached* (the packed reply frame — a retransmit re-sends it instead of
    re-executing, which is what keeps mutating handlers exactly-once under
    retry), and *evicted*.

    Memory is bounded two ways: the sender's scheduler piggybacks cumulative
    acks (``_ham/replay_ack(src, upto)`` — every msg_id <= ``upto`` is
    complete at the sender, so its cached reply can never be asked for
    again), and a FIFO cap is the backstop for senders that never ack.
    The ack watermark is also a *suppression floor*: a duplicate at or
    below it (a retransmit reordered behind the ack that evicted its cached
    reply) is dropped outright instead of re-executed — eviction must never
    reopen the exactly-once window.  An ack of ``upto >= FLUSH`` announces
    a NEW msg_id space (host restart): the cache forgets everything from
    that sender, watermark included, so low new ids neither alias old
    cached replies nor get floor-suppressed.
    Only requests carrying ``FLAG_RETRYABLE`` enter the cache — the default
    fault-free path never touches it (the <=5% hot-path overhead contract).
    """

    IN_PROGRESS = object()
    #: ack threshold meaning "sender reset its msg_id space — flush";
    #: value lives in the centralized wire-constant registry, which asserts
    #: it stays out of live msg_id space (repro.core.flags)
    FLUSH = MSG_ID_FLUSH

    def __init__(self, cap: int = 4096):
        import collections

        self._lock = threading.Lock()
        self._entries: dict[tuple[int, int], Any] = {}
        self._order: "collections.deque[tuple[int, int]]" = collections.deque()
        self._cap = int(cap)
        self._acked: dict[int, int] = {}  # src -> cumulative ack watermark
        self.stats = {"replayed": 0, "suppressed": 0, "acked": 0}

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def begin(self, src: int, msg_id: int):
        """First sight of ``(src, msg_id)`` returns None (caller executes);
        a duplicate returns IN_PROGRESS or the cached reply frame."""
        key = (src, msg_id)
        with self._lock:
            if msg_id <= self._acked.get(src, 0):
                # already complete at the sender; its cached reply may be
                # evicted, so executing again would break exactly-once —
                # drop the straggler (IN_PROGRESS: no execute, no reply)
                self.stats["suppressed"] += 1
                return self.IN_PROGRESS
            cur = self._entries.get(key)
            if cur is not None:
                # duplicate bookkeeping lives here so every dedup outcome
                # is visible in one stats dict: suppressed = swallowed
                # without reply (still executing), replayed = cached reply
                # about to be re-sent by the caller
                if cur is self.IN_PROGRESS:
                    self.stats["suppressed"] += 1
                else:
                    self.stats["replayed"] += 1
                return cur
            self._entries[key] = self.IN_PROGRESS
            self._order.append(key)
            scan = 0
            while len(self._order) > self._cap and scan < 8:
                old = self._order.popleft()
                entry = self._entries.get(old)
                if entry is self.IN_PROGRESS:
                    self._order.append(old)  # never evict a running call
                    scan += 1
                elif entry is not None:
                    del self._entries[old]
            return None

    def commit(self, src: int, msg_id: int, frame: bytes) -> None:
        """Store the packed reply frame for a call that just executed (the
        entry may have been acked/evicted concurrently — then drop it)."""
        key = (src, msg_id)
        with self._lock:
            if key in self._entries:
                self._entries[key] = frame

    def ack(self, src: int, upto: int) -> int:
        """Cumulative ack from ``src``: every msg_id <= ``upto`` is complete
        at the sender — evict their cached replies and raise the suppression
        floor.  ``upto >= FLUSH`` is the msg_id-space-reset sentinel (host
        restart): forget *everything* from ``src``, even in-progress entries
        (their commit then no-ops) and the floor itself, so the new space's
        low ids start clean.  ``_order`` keeps stale keys; eviction
        tolerates them (entries.get returns None)."""
        with self._lock:
            if upto >= self.FLUSH:
                dead = [k for k in self._entries if k[0] == src]
                self._acked.pop(src, None)
            else:
                self._acked[src] = max(self._acked.get(src, 0), int(upto))
                dead = [
                    k for k, v in self._entries.items()
                    if k[0] == src and k[1] <= upto
                    and v is not self.IN_PROGRESS
                ]
            for k in dead:
                del self._entries[k]
            self.stats["acked"] += len(dead)
        return len(dead)


def _alloc_frame(nbytes: int):
    """Writable frame buffer of ``nbytes``.

    ``bytearray(n)`` zero-fills — a full extra memory pass on multi-megabyte
    put/get payloads that the packer immediately overwrites.  Large frames
    therefore come from the (uninitialised, refcount-pooled) numpy allocator,
    wrapped in a memoryview so every consumer sees a flat byte buffer; small
    frames stay bytearray (lower constant cost).
    """
    if nbytes >= _BIG_FRAME:
        return _frame_pool.take(nbytes)
    return bytearray(nbytes)


def current_node() -> "NodeRuntime":
    node = _current_node.get()
    if node is None:
        raise OffloadError("no HAM node runtime active in this context")
    return node


# --------------------------------------------------------------------------
# internal handlers (dynamic payloads; explicit stable names)
# --------------------------------------------------------------------------


def _h_alloc(shape, dtype):
    node = current_node()
    ptr = node.buffers.allocate(shape, dtype)
    return ("ptr", ptr.node, ptr.handle, ptr.nbytes)


def _h_free(node_id, handle):
    node = current_node()
    node.buffers.free(BufferPtr(node_id, handle))
    node.dir_shard.pop(int(handle), None)  # gossip hygiene: copy is gone
    node._announce_buffer_freed(handle)


def _h_put(node_id, handle, offset, array):
    # `array` may alias the inbound frame (zero-copy unpack); the slice
    # assignment below is the single payload copy of the put path
    flat = current_node().buffers.flat(BufferPtr(node_id, handle))
    n = array.size
    flat[offset : offset + n] = array.reshape(-1).astype(flat.dtype, copy=False)


def _h_get(node_id, handle, offset, count):
    node = current_node()
    # return VIEWS: the reply is packed (= copied) before this handler's
    # dispatch ends, so the get path pays exactly one payload copy
    if count < 0 and not offset:
        return node.buffers.deref(BufferPtr(node_id, handle))  # keeps shape
    flat = node.buffers.flat(BufferPtr(node_id, handle))
    if count < 0:
        return flat[offset:]
    return flat[offset : offset + count]


def _h_ping(token):
    return token


def _h_forward(dst, frame_bytes):
    """Relay an embedded frame one hop (offload over fabric).  The final
    target replies straight to the origin recorded in the inner header."""
    node = current_node()
    node._send_frame(dst, frame_bytes)


def _h_terminate():
    current_node().request_stop()


def _h_replay_ack(src_node, upto):
    """Cumulative replay-cache ack (oneway): every msg_id <= ``upto`` from
    ``src_node`` is complete at the sender — its cached replies can go."""
    current_node().replay.ack(int(src_node), int(upto))


def _h_dir_gossip(entries):
    """Install directory-shard entries on this node (oneway; the gossip
    half of the durable directory — protocol in ``offload/dataplane``).

    Each entry is ``[handle, primary, replicas, epoch, nbytes, shape,
    dtype, session, dirty]`` (``dirty`` — the buffer's write epoch, chain
    protocol — was appended in v2; peers sending 8-element entries are
    read as ``dirty = 0``).  Installation is epoch-monotonic (``>=`` —
    holder-set changes do not bump the epoch, and per-link FIFO orders
    same-epoch updates); an entry whose holder set no longer includes this
    node — or a tombstone (``primary < 0``, the buffer was freed/lost) —
    drops the shard entry instead.
    """
    node = current_node()
    me = node.node_id
    shard = node.dir_shard
    for e in entries:
        handle, primary, replicas, epoch, nbytes, shape, dtype, session = e[:8]
        handle, primary, epoch = int(handle), int(primary), int(epoch)
        dirty = int(e[8]) if len(e) > 8 else 0
        replicas = [int(r) for r in replicas]
        if primary < 0 or (me != primary and me not in replicas):
            shard.pop(handle, None)
            node.applied_dirty.pop(handle, None)  # copy gone — the applied
            # watermark must not outlive it and vouch for a future re-adopt
            continue
        cur = shard.get(handle)
        if cur is None or epoch >= cur[2]:
            shard[handle] = (primary, replicas, epoch, int(nbytes),
                             [int(d) for d in shape], str(dtype), session,
                             dirty)


def _h_dir_dump():
    """This node's directory shard, for a restarting host's rebuild: the
    ``_ham/dir_gossip`` entry layout plus a 10th element — this node's
    ``applied_dirty`` watermark for the handle, so the rebuild can drop a
    chain tail whose bytes trail a surviving peer's write epoch (chain
    protocol, docs/failure-model.md).  Read-only: replica serving is safe,
    and a rebuild may query any survivor."""
    node = current_node()
    out = []
    for h, entry in sorted(node.dir_shard.items()):
        p, r, e, n, s, d, sess = entry[:7]
        dirty = entry[7] if len(entry) > 7 else 0
        out.append([h, p, r, e, n, s, d, sess, dirty,
                    node.applied_dirty.get(h, 0)])
    return out


def register_internal_handlers(registry=None) -> None:
    # read_only is the replica-serving contract (see HandlerRecord): True
    # only for handlers that never mutate node/buffer state.  alloc/free/put
    # mutate the buffer registry; forward re-injects traffic; terminate,
    # replay_ack and dir_gossip mutate runtime state.  get/ping/dir_dump
    # are pure reads and may be served by any replica.
    reg = registry or default_registry()
    for name, fn, read_only in (
        ("_ham/alloc", _h_alloc, False),
        ("_ham/free", _h_free, False),
        ("_ham/put", _h_put, False),
        ("_ham/get", _h_get, True),
        ("_ham/ping", _h_ping, True),
        ("_ham/forward", _h_forward, False),
        ("_ham/terminate", _h_terminate, False),
        ("_ham/replay_ack", _h_replay_ack, False),
        ("_ham/dir_gossip", _h_dir_gossip, False),
        ("_ham/dir_dump", _h_dir_dump, True),
    ):
        reg.register(fn, name=name, read_only=read_only)


# module import = static initialisation (paper §4.3)
register_internal_handlers()


# --------------------------------------------------------------------------
# the runtime
# --------------------------------------------------------------------------


class NodeRuntime:
    def __init__(
        self,
        node_id: int,
        endpoint: CommBackend,
        table: HandlerTable,
        policy: ExecutionPolicy | None = None,
        *,
        inline: bool = False,
        shape_cache: bool | None = None,
    ):
        self.node_id = node_id
        self.endpoint = endpoint
        self.table = table
        # shape-keyed WirePlan cache for dynamic payloads (FLAG_SHAPED).
        # ``None`` defers to HAM_SHAPE_CACHE (workers inherit the host's
        # environment at fork/spawn, so one env var flips both sides — the
        # benchmark's forced-TLV comparison leg relies on this).
        if shape_cache is None:
            shape_cache = os.environ.get("HAM_SHAPE_CACHE", "1") != "0"
        self._shape_cache = ShapeCache() if shape_cache else None
        # dense key-indexed fast-path arrays (compiled at HandlerTable init):
        # one list index per message instead of record attribute walks
        self._records = table.records
        self._arg_plans = table.arg_plans
        self._result_plans = table.result_plans
        #: fold sub-threshold same-destination egress frames into FLAG_FUSED
        #: multi-call frames at flush time (off => plain send_many batches).
        #: HAM_FUSE_EGRESS=0 disables it process-wide — forked workers inherit
        #: the env, which is how the relay benchmark measures the unfused leg.
        self.fuse_egress = os.environ.get("HAM_FUSE_EGRESS", "1") != "0"
        self.policy = policy or DirectPolicy()
        self.buffers = BufferRegistry(node_id)
        self.futures = FutureTable()
        self.inline = inline
        #: transport frame cap, hoisted off the endpoint once — _send_frame
        #: runs per message and must not pay a getattr per call
        self._frame_cap = getattr(endpoint, "max_frame_nbytes", None)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._sync_seq = 0  # inline futureless-sync sequence counter
        # egress coalescing: replies/oneways emitted while the event-loop
        # thread drains a batch are grouped into one send_many per dst
        self._egress: list[tuple[int, Any]] = []
        self._draining = False
        self._loop_tid: int | None = None
        self.stats = {"handled": 0, "replies": 0, "errors": 0, "sent": 0,
                      "batches": 0, "fused": 0, "replayed": 0}
        #: exactly-once dedup of FLAG_RETRYABLE requests (docs/failure-model.md)
        self.replay = ReplayCache()
        #: this node's shard of the cluster BufferDirectory — entries for
        #: buffers this node holds, installed by _ham/dir_gossip oneways and
        #: dumped to a restarting host via _ham/dir_dump (see
        #: repro.offload.dataplane for the protocol)
        self.dir_shard: dict[int, tuple] = {}
        # -- chain-replication write protocol (repro.offload.dataplane,
        # "Chain replication"; contract in docs/failure-model.md) ---------
        #: write epoch this node's bytes reflect, per handle — dumped next
        #: to the shard so a host rebuild can spot a stale chain tail
        self.applied_dirty: dict[int, int] = {}
        #: per-handle [dirty, chunks_received] for the write in flight —
        #: chunk forwards are oneways; _ham/chain_flush confirms via this
        #: count (per-link FIFO puts the flush behind every chunk)
        self.chain_seen: dict[int, list] = {}
        # -- queue-depth feedback (scheduler's remote-load signal) ---------
        #: last depth reported BY each peer via _cluster/stats oneways
        #: (populated on the node peers report to — normally the host)
        self.peer_depth: dict[int, int] = {}
        self._depth_dst: int | None = None       # report target (None = off)
        self._depth_interval = 0.05
        self._depth_record = None                # _cluster/stats HandlerRecord
        self._depth_last_sent = 0
        self._depth_last_t = 0.0
        self._batch_remaining = 0                # frames left in current drain
        #: host only: the cluster's BufferDirectory (set by ClusterPool) —
        #: _ham/buf_freed and local frees report here so replicas are
        #: invalidated cluster-wide (see repro.offload.dataplane)
        self.buffer_directory = None

    # -- queue-depth feedback ----------------------------------------------

    def enable_depth_report(self, dst: int = 0,
                            interval: float = 0.05) -> "NodeRuntime":
        """Report this node's queue depth to ``dst`` (normally the host) as
        ``_cluster/stats`` oneways — at most one per ``interval`` while busy,
        plus an immediate zero report when the queue drains, so the receiver
        never acts on a stale busy signal.  Silently disabled when the
        handler table has no ``_cluster/stats`` entry (non-cluster domains).
        """
        try:
            self._depth_record = self.table.record_of("_cluster/stats")
        except Exception:  # noqa: BLE001 — UnknownHandlerError et al.
            self._depth_record = None
            return self
        self._depth_dst = dst
        self._depth_interval = interval
        return self

    def note_peer_depth(self, node_id: int, depth: int) -> None:
        """Receiver side of the depth protocol (called by _cluster/stats)."""
        self.peer_depth[int(node_id)] = int(depth)

    def queue_depth(self) -> int:
        """Requests this node has accepted but not finished executing: the
        rest of the current drain batch plus what the transport has queued.
        The remote half of the scheduler's join-shortest-queue signal."""
        try:
            pending = self.endpoint.pending_frames()
        except Exception:  # noqa: BLE001 — estimate only, never fail dispatch
            pending = 0
        return self._batch_remaining + pending

    def _maybe_report_depth(self, force_zero: bool = False) -> None:
        """Emit a depth report if one is due.  Sends bypass the egress queue
        (a depth report parked behind the batch it describes is useless)."""
        if self._depth_dst is None:
            return
        now = time.monotonic()
        if not force_zero and now - self._depth_last_t < self._depth_interval:
            # rate limit busy reports — and skip the depth walk entirely
            # between ticks (this runs per frame on the hot path); the
            # busy->idle edge is caught by the force_zero call from the
            # loop's idle branch, which bypasses the limit
            return
        depth = 0 if force_zero else self.queue_depth()
        if depth == self._depth_last_sent:
            return
        record = self._depth_record
        args = (self.node_id, depth)
        n = mig.dynamic_nbytes(list(args))
        frame = bytearray(HEADER_NBYTES + n)
        mig.pack_dynamic_into(frame, HEADER_NBYTES, list(args))
        HEADER_STRUCT.pack_into(frame, 0, MAGIC, VERSION, FLAG_DYNAMIC,
                                self.table.key_of(record.stable_name),
                                self.node_id, 0, n)
        try:
            self.endpoint.send(self._depth_dst, frame)
        except Exception:  # noqa: BLE001 — advisory traffic must never kill
            # the loop (e.g. the host endpoint is tearing down)
            return
        self._depth_last_sent = depth
        self._depth_last_t = now

    # -- data-plane hygiene --------------------------------------------------

    def _announce_buffer_freed(self, handle: int) -> None:
        """Cluster-wide free hygiene (dataplane module docs): after this
        node drops a buffer copy, whoever tracks the directory must drop
        the record and invalidate the remaining replicas — otherwise
        ``live_count`` lies and replicas leak.  On the directory holder
        (the host) this runs in-process; a worker sends its depth-report
        destination (the host) a ``_ham/buf_freed`` oneway.  A no-op in
        non-cluster domains (no directory, no report destination)."""
        if self.buffer_directory is not None:
            from repro.offload.dataplane import _h_buf_freed

            token = _current_node.set(self)
            try:
                _h_buf_freed(self.node_id, handle)
            finally:
                _current_node.reset(token)
            return
        if self._depth_dst is None:
            return
        try:
            record = self.table.record_of("_ham/buf_freed")
        except Exception:  # noqa: BLE001 — table without the dataplane set
            return
        try:
            self.send_oneway(
                self._depth_dst, Function(record, (self.node_id, int(handle)))
            )
        except Exception:  # noqa: BLE001 — advisory traffic; the directory
            # reconciles at the holder's teardown
            pass

    # -- sending ------------------------------------------------------------

    def send_async(self, dst: int, function: Function) -> Future:
        msg_id, fut = self.futures.create()
        self._send_request(dst, function, msg_id)
        return fut

    def send_oneway(self, dst: int, function: Function) -> None:
        """Fire-and-forget (msg_id 0 => no reply)."""
        self._send_request(dst, function, 0)

    def _send_frame(self, dst: int, frame) -> None:
        """Transport egress: coalesced while the loop thread drains a batch,
        immediate otherwise (user threads never see queueing)."""
        cap = self._frame_cap
        if cap is not None and len(frame) > cap:
            # fail fast, HERE: parking an oversized frame in the egress queue
            # would defer the error past the handler's error-reply wrapping
            from repro.core.errors import CommError

            raise CommError(
                f"frame of {len(frame)} bytes exceeds transport frame "
                f"capacity {cap}"
            )
        if self._draining and threading.get_ident() == self._loop_tid:
            self._egress.append((dst, frame))
        else:
            self.endpoint.send(dst, frame)

    def _flush_egress(self) -> None:
        if not self._egress or threading.get_ident() != self._loop_tid:
            return
        egress, self._egress = self._egress, []
        if len(egress) == 1:
            dst, frame = egress[0]
            self.endpoint.send(dst, frame)
            return
        by_dst: dict[int, list] = {}
        for dst, frame in egress:
            by_dst.setdefault(dst, []).append(frame)
        for dst, frames in by_dst.items():
            if self.fuse_egress and len(frames) > 1:
                frames = self._fuse_runs(frames)
            if len(frames) == 1:
                self.endpoint.send(dst, frames[0])
            else:
                self.endpoint.send_many(dst, frames)

    def _fusible(self, frame) -> bool:
        """May this packed egress frame fold into a fused batch?  Small and
        not itself fused.  A relayed ``_ham/forward`` inner frame (foreign
        src_node) IS fusible: its true origin travels as a ``FLAG_SEG_SRC``
        payload prefix so multi-hop topologies keep the fused win."""
        if len(frame) > HEADER_NBYTES + FUSE_THRESHOLD:
            return False
        _, _, flags, _, _, _, _ = HEADER_STRUCT.unpack_from(frame, 0)
        return not flags & FLAG_FUSED

    def _fuse_runs(self, frames: list) -> list:
        """Fold consecutive runs of fusible frames (length >= 2) into
        FLAG_FUSED frames, preserving per-destination frame order."""
        out: list = []
        run: list = []
        for frame in frames:
            if self._fusible(frame):
                run.append(frame)
                if len(run) == FUSE_MAX_SEGMENTS:
                    out.append(self._fuse_frames(run))
                    run = []
                continue
            if len(run) == 1:
                out.append(run[0])
            elif run:
                out.append(self._fuse_frames(run))
            run = []
            out.append(frame)
        if len(run) == 1:
            out.append(run[0])
        elif run:
            out.append(self._fuse_frames(run))
        return out

    def _fuse_frames(self, frames: list):
        """Rewrite N packed frames into one FLAG_FUSED frame (segment layout
        in ``core/message.py``): N-1 headers and N-1 transport publications
        amortised into one, decoded by the receiver in a single pass.

        Frames whose src_node is not this node (relayed ``_ham/forward``
        inner frames re-emitted at the forwarder) become ``FLAG_SEG_SRC``
        segments carrying their true origin as a u32 payload prefix — the
        receiver dispatches and replies against the origin, preserving the
        forward contract (final target answers the origin directly)."""
        me = self.node_id
        heads = [HEADER_STRUCT.unpack_from(f, 0) for f in frames]
        total = 4 + sum(
            len(f) - HEADER_NBYTES + SEG_NBYTES
            + (SEG_SRC_NBYTES if h[4] != me else 0)
            for f, h in zip(frames, heads)
        )
        fused = _alloc_frame(HEADER_NBYTES + total)
        HEADER_STRUCT.pack_into(fused, 0, MAGIC, VERSION, FLAG_FUSED, 0,
                                me, 0, total)
        FUSED_COUNT_STRUCT.pack_into(fused, HEADER_NBYTES, len(frames))
        off = HEADER_NBYTES + 4
        for f, (_, _, flags, key, src, msg_id, plen) in zip(frames, heads):
            if src != me:
                SEG_STRUCT.pack_into(fused, off, key, flags | FLAG_SEG_SRC,
                                     msg_id, plen + SEG_SRC_NBYTES)
                off += SEG_NBYTES
                SEG_SRC_STRUCT.pack_into(fused, off, src)
                off += SEG_SRC_NBYTES
            else:
                SEG_STRUCT.pack_into(fused, off, key, flags, msg_id, plen)
                off += SEG_NBYTES
            end = HEADER_NBYTES + plen
            fused[off : off + plen] = (
                f[HEADER_NBYTES:end] if isinstance(f, (bytes, bytearray))
                else memoryview(f)[HEADER_NBYTES:end]
            )
            off += plen
        self.stats["fused"] += len(frames)
        return fused

    def _send_request(self, dst: int, function: Function, msg_id: int,
                      extra_flags: int = 0) -> None:
        # zero-extra-copy frame assembly: the frame is allocated at its exact
        # final size and the payload packed straight in after the 32-byte
        # header.  Static-spec handlers ride the compiled WirePlan (exact
        # nbytes known up front, one fused struct call for scalar leaves);
        # dynamic handlers fall back to measured TLV.  ``extra_flags`` ORs in
        # caller bits (FLAG_RETRYABLE for deadline/retry calls).
        key = self.table.key_of(function.record.stable_name)
        plan = self._arg_plans[key]
        if plan is not None:
            n = plan.nbytes
            frame = _alloc_frame(HEADER_NBYTES + n)
            plan.pack_args(frame, HEADER_NBYTES, function.args)
            flags = FLAG_STATIC | extra_flags
        else:
            frame = n = None
            # dynamic handler: repeat shapes ride a cached WirePlan
            # (FLAG_SHAPED) — straight-line pack instead of the TLV walk
            shaped = (self._shape_cache.for_values(function.args, "A")
                      if self._shape_cache is not None else None)
            if shaped is not None:
                sig, splan = shaped
                n = SIG_LEN_NBYTES + len(sig) + splan.nbytes
                frame = _alloc_frame(HEADER_NBYTES + n)
                SIG_LEN_STRUCT.pack_into(frame, HEADER_NBYTES, len(sig))
                body = HEADER_NBYTES + SIG_LEN_NBYTES
                frame[body : body + len(sig)] = sig
                try:
                    splan.pack_args(frame, body + len(sig), function.args)
                    flags = FLAG_SHAPED | extra_flags
                except Exception:  # noqa: BLE001 — e.g. a misbehaving opaque
                    # codec; the TLV path below is always a valid encoding
                    frame = None
            if frame is None:
                args = list(function.args)
                n = mig.dynamic_nbytes(args)
                frame = _alloc_frame(HEADER_NBYTES + n)
                mig.pack_dynamic_into(frame, HEADER_NBYTES, args)
                flags = FLAG_DYNAMIC | extra_flags
        HEADER_STRUCT.pack_into(frame, 0, MAGIC, VERSION, flags, key,
                                self.node_id, msg_id, n)
        self._send_frame(dst, frame)
        self.stats["sent"] += 1

    def send_fused(self, dst: int, functions) -> list[Future]:
        """Submit many calls as ONE ``FLAG_FUSED`` frame; futures in order.

        The caller-side half of small-call fusion: one header, one transport
        publication and one receiver dispatch pass for the whole batch.  Any
        registered handler may appear (static calls plan-pack, dynamic calls
        TLV-pack into their segments); batches larger than
        ``FUSE_MAX_SEGMENTS`` split into multiple fused frames.  Replies
        resolve each call's future individually — an error in one call
        rejects only that future.

        All-or-nothing on failure: every frame is packed BEFORE anything is
        sent, and any pack/send error discards every created future (so a
        spec-violating call cannot strand earlier sub-batches' replies on
        futures the caller never received) and re-raises to the caller.
        """
        functions = list(functions)
        created = [self.futures.create() for _ in functions]
        calls = [(fn, msg_id) for fn, (msg_id, _) in zip(functions, created)]
        try:
            frames = [
                self._pack_fused_frame(calls[start : start + FUSE_MAX_SEGMENTS])
                for start in range(0, len(calls), FUSE_MAX_SEGMENTS)
            ]
            for frame in frames:
                self._send_frame(dst, frame)
        except Exception:
            # popped table entries drop any straggler reply for these ids
            for msg_id, _ in created:
                self.futures.discard(msg_id)
            raise
        self.stats["sent"] += len(calls)
        return [fut for _, fut in created]

    def _send_fused_request(self, dst: int, calls) -> None:
        """Pack ``[(function, msg_id), ...]`` into one fused frame and send."""
        self._send_frame(dst, self._pack_fused_frame(calls))
        self.stats["sent"] += len(calls)

    def send_oneway_fused(self, dst: int, functions) -> None:
        """Fire-and-forget batch as ``FLAG_FUSED`` frames: one header and
        one transport publication per ``FUSE_MAX_SEGMENTS`` calls, zero
        replies (every segment carries ``msg_id = 0``).  The oneway half of
        :meth:`send_fused` — an invalidation/gossip storm to one
        destination collapses to one frame instead of one send per call."""
        calls = [(fn, 0) for fn in functions]
        if len(calls) == 1:
            self.send_oneway(dst, calls[0][0])
            return
        for start in range(0, len(calls), FUSE_MAX_SEGMENTS):
            self._send_fused_request(dst, calls[start : start + FUSE_MAX_SEGMENTS])

    def _pack_fused_frame(self, calls):
        """One FLAG_FUSED frame for ``[(function, msg_id), ...]``."""
        key_of = self.table.key_of
        plans = self._arg_plans
        cache = self._shape_cache
        metas = []
        total = 4
        for fn, msg_id in calls:
            key = key_of(fn.record.stable_name)
            plan = plans[key]
            sig = None
            if plan is not None:
                n, flags = plan.nbytes, FLAG_STATIC
            else:
                shaped = (cache.for_values(fn.args, "A")
                          if cache is not None else None)
                if shaped is not None:
                    sig, plan = shaped
                    n = SIG_LEN_NBYTES + len(sig) + plan.nbytes
                    flags = FLAG_SHAPED
                else:
                    n, flags = mig.dynamic_nbytes(list(fn.args)), FLAG_DYNAMIC
            metas.append((key, flags, msg_id, n, plan, sig, fn.args))
            total += SEG_NBYTES + n
        frame = _alloc_frame(HEADER_NBYTES + total)
        HEADER_STRUCT.pack_into(frame, 0, MAGIC, VERSION, FLAG_FUSED, 0,
                                self.node_id, 0, total)
        FUSED_COUNT_STRUCT.pack_into(frame, HEADER_NBYTES, len(metas))
        off = HEADER_NBYTES + 4
        for key, flags, msg_id, n, plan, sig, args in metas:
            SEG_STRUCT.pack_into(frame, off, key, flags, msg_id, n)
            off += SEG_NBYTES
            if sig is not None:
                SIG_LEN_STRUCT.pack_into(frame, off, len(sig))
                frame[off + SIG_LEN_NBYTES : off + SIG_LEN_NBYTES + len(sig)] = sig
                plan.pack_args(frame, off + SIG_LEN_NBYTES + len(sig), args)
            elif plan is not None:
                plan.pack_args(frame, off, args)
            else:
                mig.pack_dynamic_into(frame, off, list(args))
            off += n
        return frame

    def send_sync(self, dst: int, function: Function, timeout: float | None = 30.0):
        if self.inline:
            return self._send_sync_inline(dst, function, timeout)
        fut = self.send_async(dst, function)
        return fut.get(timeout)

    def _send_sync_inline(self, dst: int, function: Function,
                          timeout: float | None):
        """Futureless fast path (the Fig. 3 configuration): the caller
        thread polls its endpoint for the reply — no Future allocation, no
        Event wakeup, no table lock.  Interleaved requests still execute."""
        self._sync_seq += 1
        msg_id = 0x8000_0000_0000_0000 | self._sync_seq
        self._send_request(dst, function, msg_id)
        recv = self.endpoint.recv
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            frame = recv(timeout=0.1)
            if frame is None:
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError("inline sync offload timed out")
                continue
            key, flags, src, mid, payload = decode_fast(frame)
            if flags & FLAG_FUSED:
                # our reply may ride a fused batch (the responder coalesces
                # same-destination replies): peel our segment, dispatch the
                # rest through the normal path
                mine = None
                for skey, sflags, smid, seg in iter_fused(payload):
                    if sflags & FLAG_SEG_SRC:  # relayed segment: strip prefix
                        (sseg_src,) = SEG_SRC_STRUCT.unpack_from(seg, 0)
                        seg = seg[SEG_SRC_NBYTES:]
                        sflags &= ~FLAG_SEG_SRC
                    else:
                        sseg_src = src
                    if mine is None and sflags & FLAG_REPLY and smid == msg_id:
                        mine = (skey, sflags, seg)
                    else:
                        self._handle_one(skey, sflags, sseg_src, smid, seg, True)
                if mine is None:
                    continue
                return self._finish_sync_reply(*mine)
            if flags & FLAG_REPLY and mid == msg_id:
                return self._finish_sync_reply(key, flags, payload)
            self._handle_frame(frame)

    def _finish_sync_reply(self, key, flags, payload):
        """Inline-sync tail: same decode as the event loop (_decode_reply),
        raised instead of routed through a future."""
        value, err = self._decode_reply(key, flags, payload)
        if err is not None:
            from repro.core.errors import RemoteExecutionError

            raise RemoteExecutionError(err[0], err[1])
        return value

    def _inline_wait(self, fut: Future, timeout: float | None):
        """Caller-thread polling: the lowest-latency mode (no wakeup hop).
        Interleaved inbound requests are still served, so reverse offload
        works even in inline mode."""
        # a handler waiting mid-batch must not deadlock on its own parked
        # egress (e.g. a request it just sent): push it out before blocking
        self._flush_egress()
        deadline = None if timeout is None else time.monotonic() + timeout
        while not fut.done():
            frame = self.endpoint.recv(timeout=0.1)
            if frame is not None:
                self._handle_frame(frame)
                self._flush_egress()
            elif deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("inline sync offload timed out")
        return fut.get(0)

    def wait(self, fut: Future, timeout: float | None = 30.0):
        """Cooperatively wait on a future *from handler context*.

        With the Direct execution policy the handler runs on the event-loop
        thread; plain ``fut.get()`` there would deadlock (the loop cannot pump
        the reply).  ``wait`` keeps servicing inbound frames while blocked —
        the cooperative-runtime pattern the paper's execution policies enable.
        With a thread-pool policy, plain ``fut.get()`` is also fine.
        """
        return self._inline_wait(fut, timeout)

    # -- receiving ------------------------------------------------------------

    def _handle_frame(self, frame, owned: bool = True) -> None:
        # hot path: the paper's metric is exactly this function's cost.
        # ``owned=False`` marks a leased transport view: anything escaping
        # this call (futures, deferred execution) must copy first.
        key, flags, src, msg_id, payload = decode_fast(frame)
        if flags & FLAG_FUSED:
            self._handle_fused(src, payload, owned)
        else:
            self._handle_one(key, flags, src, msg_id, payload, owned)

    def _handle_one(self, key, flags, src, msg_id, payload, owned) -> None:
        """Dispatch one logical message (a standalone frame's decode or one
        fused segment)."""
        if flags & FLAG_REPLY:
            self.stats["replies"] += 1
            if not owned:
                payload = bytes(payload)  # escapes into the future table
            value, err = self._decode_reply(key, flags, payload)
            if err is None:
                self.futures.resolve(msg_id, value)
            else:
                self.futures.reject(msg_id, err[0], err[1])
            return
        try:
            record = self._records[key]
            plan = self._arg_plans[key]
        except IndexError:
            self.table.handler_at(key)  # raises the same-source diagnostic
            raise
        if type(self.policy) is DirectPolicy:  # skip the closure on the hot path
            # executes before the lease is released — views are safe in place
            self._execute(record, plan, key, flags, src, msg_id, payload)
        else:
            if not owned:
                payload = bytes(payload)  # outlives the drain iteration
            self.policy.submit(lambda: self._execute(record, plan, key, flags,
                                                     src, msg_id, payload))

    def _handle_fused(self, src, payload, owned) -> None:
        """One FLAG_FUSED frame => N logical messages, one dispatch pass.

        Replies resolve inline (cheap, and futures are thread-safe);
        request segments execute in order — for a pooled policy all of them
        ride a single ``submit`` (the single-executor-pass contract), so a
        fused batch costs one task switch, not N.
        """
        direct = type(self.policy) is DirectPolicy
        if not owned and not direct:
            # one copy for the whole batch (deferred segments outlive the
            # lease); direct execution stays in place and reply segments
            # are copied individually by _handle_one
            payload = memoryview(bytes(payload))
            owned = True
        # a fused frame often arrives as a singleton drain batch (which runs
        # undrained for latency): park this batch's replies regardless so
        # they flush as ONE fused reply frame — fusion's return half
        restore_drain = (
            direct and not self._draining
            and threading.get_ident() == self._loop_tid
        )
        if restore_drain:
            self._draining = True
        deferred = None
        # one contextvar bracket for the whole batch (direct policy executes
        # segments inline here) — ~0.4 us per call saved at fusion densities
        token = _current_node.set(self) if direct else None
        try:
            for key, flags, msg_id, seg in iter_fused(payload):
                if flags & FLAG_SEG_SRC:
                    # relayed segment: true origin rides a u32 payload prefix
                    # (relay-aware fusion — see core/message.py); dispatch
                    # and reply against the origin, exactly as the unfused
                    # _ham/forward inner frame would have
                    (seg_src,) = SEG_SRC_STRUCT.unpack_from(seg, 0)
                    seg = seg[SEG_SRC_NBYTES:]
                    flags &= ~FLAG_SEG_SRC
                else:
                    seg_src = src
                if flags & FLAG_REPLY:
                    self._handle_one(key, flags, seg_src, msg_id, seg, owned)
                    continue
                try:
                    record = self._records[key]
                    plan = self._arg_plans[key]
                except IndexError:
                    self.table.handler_at(key)
                    raise
                if direct:
                    self._execute_gated(record, plan, key, flags, seg_src,
                                        msg_id, seg)
                else:
                    if deferred is None:
                        deferred = []
                    deferred.append((record, plan, key, flags, seg_src,
                                     msg_id, seg))
        finally:
            if token is not None:
                _current_node.reset(token)
            if restore_drain:
                self._draining = False
                self._flush_egress()
        if deferred:
            def _run_batch(batch=deferred):
                for item in batch:
                    self._execute(*item)
            self.policy.submit(_run_batch)

    def _decode_reply(self, key, flags, payload):
        """Shared reply decode (event loop AND inline-sync path): returns
        ``(value, None)`` or ``(None, (msg, tb))`` for an error reply.

        ``FLAG_STATIC`` selects the handler's compiled result plan;
        ``FLAG_SHAPED`` decodes through the shape cache (signature-keyed
        plan); error replies and un-flagged replies (pre-plan peers) are
        dynamic TLV.
        """
        if flags & FLAG_ERROR:
            err = mig.unpack_dynamic(payload)
            return None, (err["msg"], err.get("tb", ""))
        if flags & FLAG_SHAPED:
            cache = self._shape_cache
            if cache is None:
                cache = self._shape_cache = ShapeCache()
            return cache.unpack_shaped(payload, expect_args=False), None
        if flags & FLAG_STATIC:
            try:
                plan = self._result_plans[key]
            except IndexError:
                plan = None
            if plan is None:
                raise MessageFormatError(
                    f"STATIC reply for key {key} but no local result plan; "
                    "peer key maps diverge (same-source assumption violated)"
                )
            return plan.unpack_result(payload), None
        return mig.unpack_dynamic(payload), None

    def _execute(self, record, plan, key, flags, src, msg_id, payload) -> None:
        token = _current_node.set(self)  # policy may run on a pool thread
        try:
            self._execute_gated(record, plan, key, flags, src, msg_id, payload)
        finally:
            _current_node.reset(token)

    def _execute_gated(self, record, plan, key, flags, src, msg_id,
                       payload) -> None:
        """:meth:`_execute` minus the contextvar bracket (a fused batch sets
        the contextvar once around its whole segment loop)."""
        # exactly-once gate: a FLAG_RETRYABLE request may be a sender
        # retransmission.  First sighting marks the key in-progress and
        # executes; a duplicate with the reply already cached resends that
        # frame verbatim; a duplicate still in flight is dropped (the reply
        # of the in-progress execution answers both).  Fault-free cost is
        # one flags test — non-retryable calls never touch the cache.
        retry_key = None
        if flags & FLAG_RETRYABLE and msg_id:
            cached = self.replay.begin(src, msg_id)
            if cached is not None:
                if cached is not ReplayCache.IN_PROGRESS:
                    self.stats["replayed"] += 1
                    self._send_frame(src, cached)
                return
            retry_key = (src, msg_id)
        self._execute_in_ctx(record, plan, key, flags, src, msg_id,
                             payload, retry_key)

    def _execute_in_ctx(self, record, plan, key, flags, src, msg_id, payload,
                        retry_key) -> None:
        """Decode, run the handler, and reply — the innermost execute step
        (contextvar and replay gate handled by the callers above)."""
        self.stats["handled"] += 1
        try:
            # wire compat: a pre-plan peer sends static payloads with no
            # flag bits — the plan decodes them regardless (identical
            # layout); FLAG_DYNAMIC forces the TLV path either way
            if flags & FLAG_SHAPED:
                args = self._shaped_args(payload)
            elif plan is not None and not flags & FLAG_DYNAMIC:
                args = plan.unpack_args(payload)
            else:
                args = tuple(mig.unpack_dynamic(payload))
            result = record.fn(*args)
        except Exception as e:  # noqa: BLE001 — remote errors must travel
            self.stats["errors"] += 1
            if msg_id:
                frame = self._send_reply(
                    src, key, msg_id,
                    {"msg": f"{type(e).__name__}: {e}",
                     "tb": traceback.format_exc()},
                    FLAG_REPLY | FLAG_ERROR)
                if retry_key:
                    self.replay.commit(src, msg_id, bytes(frame))
            return
        if msg_id:
            try:
                frame = self._send_reply(src, key, msg_id, result,
                                         FLAG_REPLY,
                                         self._result_plans[key])
            except Exception as e:  # noqa: BLE001 — e.g. reply exceeds the
                # transport frame limit, or the result violates the
                # handler's declared result spec: the caller must get an
                # error, not a dead worker and a timeout
                self.stats["errors"] += 1
                frame = self._send_reply(
                    src, key, msg_id,
                    {"msg": f"{type(e).__name__}: {e}",
                     "tb": traceback.format_exc()},
                    FLAG_REPLY | FLAG_ERROR,
                )
            if retry_key:
                self.replay.commit(src, msg_id, bytes(frame))

    def _shaped_args(self, payload) -> tuple:
        """Decode a FLAG_SHAPED request payload to an args tuple.  A receiver
        with the cache disabled still decodes shaped frames (the flag is a
        wire format, not a capability negotiation) through a lazily created
        cache."""
        cache = self._shape_cache
        if cache is None:
            cache = self._shape_cache = ShapeCache()
        return cache.unpack_shaped(payload, expect_args=True)

    def _send_reply(self, dst: int, key: int, msg_id: int, result, flags,
                    plan=None):
        if plan is not None and not flags & FLAG_ERROR:
            # static result fast path: exact-size frame, plan-packed payload
            n = plan.nbytes
            frame = _alloc_frame(HEADER_NBYTES + n)
            plan.pack_result(frame, HEADER_NBYTES, result)
            flags |= FLAG_STATIC
        else:
            frame = None
            if not flags & FLAG_ERROR and self._shape_cache is not None:
                # dynamic-handler reply: repeat shapes ride a cached plan
                # (FLAG_SHAPED) exactly like shaped requests
                shaped = self._shape_cache.for_result(result)
                if shaped is not None:
                    sig, splan = shaped
                    values = result if isinstance(result, tuple) else (result,)
                    n = SIG_LEN_NBYTES + len(sig) + splan.nbytes
                    frame = _alloc_frame(HEADER_NBYTES + n)
                    SIG_LEN_STRUCT.pack_into(frame, HEADER_NBYTES, len(sig))
                    body = HEADER_NBYTES + SIG_LEN_NBYTES
                    frame[body : body + len(sig)] = sig
                    try:
                        splan.pack_args(frame, body + len(sig), values)
                        flags |= FLAG_SHAPED
                    except Exception:  # noqa: BLE001 — fall back to TLV
                        frame = None
            if frame is None:
                n = mig.dynamic_nbytes(result)
                frame = _alloc_frame(HEADER_NBYTES + n)
                mig.pack_dynamic_into(frame, HEADER_NBYTES, result)
                flags |= FLAG_DYNAMIC
        HEADER_STRUCT.pack_into(frame, 0, MAGIC, VERSION, flags,
                                key, self.node_id, msg_id, n)
        self._send_frame(dst, frame)
        return frame

    # -- event loop -----------------------------------------------------------

    def run(self, poll_timeout: float = 0.1) -> None:
        """Batch-drain event loop: pull up to ``_DRAIN_BATCH`` frames per
        ``recv_many``, dispatch them (decoding in place from leased views on
        zero-copy transports), release the lease, then flush the coalesced
        egress — one transport publication per drain iteration."""
        ep = self.endpoint
        leased = getattr(ep, "zero_copy_recv", False)
        self._loop_tid = threading.get_ident()
        while not self._stop.is_set():
            frames = ep.recv_many(_DRAIN_BATCH, timeout=poll_timeout)
            if not frames:
                # idle: retract any stale busy signal so the scheduler does
                # not keep routing around a worker that already drained
                self._maybe_report_depth(force_zero=True)
                continue
            self.stats["batches"] += 1
            # singleton batches (the latency-sensitive ping-pong case) skip
            # the egress parking: there is nothing to coalesce a lone reply
            # with, and the park+flush detour costs ~1us per round trip
            self._draining = len(frames) > 1
            self._batch_remaining = len(frames)
            report_depth = self._depth_dst is not None
            try:
                for frame in frames:
                    if report_depth:
                        # report BEFORE executing: a long handler must not
                        # hide the queue that is forming behind it
                        self._maybe_report_depth()
                    try:
                        self._handle_frame(frame, owned=not leased)
                    except Exception:  # noqa: BLE001 — a poison frame must
                        # not kill the event loop (remaining frames, futures
                        # and peers all depend on it staying alive)
                        self.stats["errors"] += 1
                        traceback.print_exc()
                    self._batch_remaining -= 1
            finally:
                self._draining = False
                self._batch_remaining = 0
                # drop frame refs BEFORE blocking in the next recv_many:
                # holding them would pin pooled frame buffers (and leased
                # ring space) across the idle wait
                frame = frames = None
                ep.release()  # return window space before the egress flush
                try:
                    self._flush_egress()
                except Exception:  # noqa: BLE001 — a failed send must not
                    # take down the loop; peers/futures depend on it
                    self.stats["errors"] += 1
                    traceback.print_exc()

    def start(self) -> "NodeRuntime":
        if self.inline:
            raise OffloadError("inline runtimes poll from the caller thread")
        self._thread = threading.Thread(
            target=self.run, name=f"ham-node-{self.node_id}", daemon=True
        )
        self._thread.start()
        return self

    def request_stop(self) -> None:
        self._stop.set()

    def stop(self, timeout: float = 5.0) -> None:
        self.request_stop()
        if self._thread is not None:
            self._thread.join(timeout)
        n = self.futures.fail_all(NodeDownError(f"node {self.node_id} stopped"))
        if n:
            self.stats["errors"] += n
