"""Per-node active-message runtime: the "minimal runtime" of HAM-Offload.

One :class:`NodeRuntime` per process/thread-node:

* pulls frames from its comm endpoint,
* replies are routed to the sender's :class:`FutureTable` (the
  ``offload_result_msg`` path of paper Fig. 5),
* requests are executed through the node's :class:`ExecutionPolicy`; if the
  frame carries a ``msg_id`` the result is packed and sent back as a REPLY
  frame (errors as REPLY|ERROR with the remote traceback).

Internal handlers (registered at import, i.e. "static initialisation", with
explicit names so they sort deterministically — cf. the paper's
``terminate_functor`` appearing in its Fig. 7 dump):

* ``_ham/alloc``, ``_ham/free``, ``_ham/put``, ``_ham/get`` — buffer plane
* ``_ham/ping`` — liveness/barrier
* ``_ham/forward`` — one-hop relay (offload-over-fabric routing)
* ``_ham/terminate`` — stops the event loop

Handlers executing on a node can access "their" node via
:func:`current_node` (contextvar set around execution) — this is how
offloaded user code dereferences :class:`BufferPtr` arguments and how
*reverse offload* (worker calling back into the host) gets a sender.
"""

from __future__ import annotations

import contextvars
import threading
import traceback
from typing import Any

from repro.comm.base import CommBackend
from repro.core import migratable as mig
from repro.core.closure import Function
from repro.core.errors import NodeDownError, OffloadError
from repro.core.future import Future, FutureTable
from repro.core.executor import DirectPolicy, ExecutionPolicy
from repro.core.message import (
    FLAG_DYNAMIC,
    FLAG_ERROR,
    FLAG_REPLY,
    HEADER_NBYTES,
    HEADER_STRUCT,
    MAGIC,
    VERSION,
    decode_fast,
    encode_frame,
)
from repro.core.migratable import _pack_into, static_payload_nbytes
from repro.core.registry import HandlerTable, default_registry
from repro.offload.buffer import BufferPtr, BufferRegistry

_current_node: contextvars.ContextVar["NodeRuntime | None"] = contextvars.ContextVar(
    "ham_current_node", default=None
)


def current_node() -> "NodeRuntime":
    node = _current_node.get()
    if node is None:
        raise OffloadError("no HAM node runtime active in this context")
    return node


# --------------------------------------------------------------------------
# internal handlers (dynamic payloads; explicit stable names)
# --------------------------------------------------------------------------


def _h_alloc(shape, dtype):
    node = current_node()
    ptr = node.buffers.allocate(shape, dtype)
    return ("ptr", ptr.node, ptr.handle)


def _h_free(node_id, handle):
    current_node().buffers.free(BufferPtr(node_id, handle))
    return None


def _h_put(node_id, handle, offset, array):
    buf = current_node().buffers.deref(BufferPtr(node_id, handle))
    flat = buf.reshape(-1)
    n = array.size
    flat[offset : offset + n] = array.reshape(-1).astype(buf.dtype, copy=False)
    return None


def _h_get(node_id, handle, offset, count):
    buf = current_node().buffers.deref(BufferPtr(node_id, handle))
    flat = buf.reshape(-1)
    if count < 0:
        return flat[offset:].copy() if offset else buf.copy()
    return flat[offset : offset + count].copy()


def _h_ping(token):
    return token


def _h_forward(dst, frame_bytes):
    """Relay an embedded frame one hop (offload over fabric).  The final
    target replies straight to the origin recorded in the inner header."""
    current_node().endpoint.send(dst, frame_bytes)
    return None


def _h_terminate():
    current_node().request_stop()
    return None


def register_internal_handlers(registry=None) -> None:
    reg = registry or default_registry()
    for name, fn in (
        ("_ham/alloc", _h_alloc),
        ("_ham/free", _h_free),
        ("_ham/put", _h_put),
        ("_ham/get", _h_get),
        ("_ham/ping", _h_ping),
        ("_ham/forward", _h_forward),
        ("_ham/terminate", _h_terminate),
    ):
        reg.register(fn, name=name)


# module import = static initialisation (paper §4.3)
register_internal_handlers()


# --------------------------------------------------------------------------
# the runtime
# --------------------------------------------------------------------------


class NodeRuntime:
    def __init__(
        self,
        node_id: int,
        endpoint: CommBackend,
        table: HandlerTable,
        policy: ExecutionPolicy | None = None,
        *,
        inline: bool = False,
    ):
        self.node_id = node_id
        self.endpoint = endpoint
        self.table = table
        self.policy = policy or DirectPolicy()
        self.buffers = BufferRegistry(node_id)
        self.futures = FutureTable()
        self.inline = inline
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.stats = {"handled": 0, "replies": 0, "errors": 0, "sent": 0}

    # -- sending ------------------------------------------------------------

    def send_async(self, dst: int, function: Function) -> Future:
        msg_id, fut = self.futures.create()
        self._send_request(dst, function, msg_id)
        return fut

    def send_oneway(self, dst: int, function: Function) -> None:
        """Fire-and-forget (msg_id 0 => no reply)."""
        self._send_request(dst, function, 0)

    def _send_request(self, dst: int, function: Function, msg_id: int) -> None:
        # zero-extra-copy frame assembly: payload is packed straight into
        # the frame buffer after the 32-byte header (the bitwise fast path)
        record = function.record
        key = self.table.key_of(record.stable_name)
        if record.is_static:
            n = static_payload_nbytes(record.arg_specs)
            frame = bytearray(HEADER_NBYTES + n)
            mig.pack_static(function.args, record.arg_specs,
                            out=memoryview(frame)[HEADER_NBYTES:])
            flags = 0
        else:
            frame = bytearray(HEADER_NBYTES)
            _pack_into(frame, list(function.args))
            n = len(frame) - HEADER_NBYTES
            flags = FLAG_DYNAMIC
        HEADER_STRUCT.pack_into(frame, 0, MAGIC, VERSION, flags, key,
                                self.node_id, msg_id, n)
        self.endpoint.send(dst, frame)
        self.stats["sent"] += 1

    def send_sync(self, dst: int, function: Function, timeout: float | None = 30.0):
        if self.inline:
            return self._send_sync_inline(dst, function, timeout)
        fut = self.send_async(dst, function)
        return fut.get(timeout)

    def _send_sync_inline(self, dst: int, function: Function,
                          timeout: float | None):
        """Futureless fast path (the Fig. 3 configuration): the caller
        thread polls its endpoint for the reply — no Future allocation, no
        Event wakeup, no table lock.  Interleaved requests still execute."""
        _time = __import__("time")
        self._sync_seq = getattr(self, "_sync_seq", 0) + 1
        msg_id = 0x8000_0000_0000_0000 | self._sync_seq
        self._send_request(dst, function, msg_id)
        recv = self.endpoint.recv
        deadline = None if timeout is None else _time.monotonic() + timeout
        while True:
            frame = recv(timeout=0.1)
            if frame is None:
                if deadline is not None and _time.monotonic() > deadline:
                    raise TimeoutError("inline sync offload timed out")
                continue
            key, flags, src, mid, payload = decode_fast(frame)
            if flags & FLAG_REPLY and mid == msg_id:
                if flags & FLAG_ERROR:
                    err = mig.unpack_dynamic(payload)
                    from repro.core.errors import RemoteExecutionError

                    raise RemoteExecutionError(err["msg"], err.get("tb", ""))
                return mig.unpack_dynamic(payload)
            self._handle_frame(frame)

    def _inline_wait(self, fut: Future, timeout: float | None):
        """Caller-thread polling: the lowest-latency mode (no wakeup hop).
        Interleaved inbound requests are still served, so reverse offload
        works even in inline mode."""
        import time

        deadline = None if timeout is None else time.monotonic() + timeout
        while not fut.done():
            frame = self.endpoint.recv(timeout=0.1)
            if frame is not None:
                self._handle_frame(frame)
            elif deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("inline sync offload timed out")
        return fut.get(0)

    def wait(self, fut: Future, timeout: float | None = 30.0):
        """Cooperatively wait on a future *from handler context*.

        With the Direct execution policy the handler runs on the event-loop
        thread; plain ``fut.get()`` there would deadlock (the loop cannot pump
        the reply).  ``wait`` keeps servicing inbound frames while blocked —
        the cooperative-runtime pattern the paper's execution policies enable.
        With a thread-pool policy, plain ``fut.get()`` is also fine.
        """
        return self._inline_wait(fut, timeout)

    # -- receiving ------------------------------------------------------------

    def _handle_frame(self, frame: bytes) -> None:
        # hot path: the paper's metric is exactly this function's cost
        key, flags, src, msg_id, payload = decode_fast(frame)
        if flags & FLAG_REPLY:
            self.stats["replies"] += 1
            if flags & FLAG_ERROR:
                err = mig.unpack_dynamic(payload)
                self.futures.reject(msg_id, err["msg"], err.get("tb", ""))
            else:
                self.futures.resolve(msg_id, mig.unpack_dynamic(payload))
            return
        record = self.table.handler_at(key)
        if type(self.policy) is DirectPolicy:  # skip the closure on the hot path
            self._execute(record, key, src, msg_id, payload)
        else:
            self.policy.submit(lambda: self._execute(record, key, src, msg_id,
                                                     payload))

    def _execute(self, record, key, src, msg_id, payload) -> None:
        token = _current_node.set(self)  # policy may run on a pool thread
        try:
            self.stats["handled"] += 1
            try:
                args = Function.unpack_args(record, payload)
                result = record.fn(*args)
            except Exception as e:  # noqa: BLE001 — remote errors must travel
                self.stats["errors"] += 1
                if msg_id:
                    err_payload = mig.pack_dynamic(
                        {"msg": f"{type(e).__name__}: {e}", "tb": traceback.format_exc()}
                    )
                    self.endpoint.send(
                        src,
                        encode_frame(key, err_payload, src_node=self.node_id,
                                     msg_id=msg_id, flags=FLAG_REPLY | FLAG_ERROR),
                    )
                return
            if msg_id:
                frame = bytearray(HEADER_NBYTES)
                _pack_into(frame, result)
                HEADER_STRUCT.pack_into(frame, 0, MAGIC, VERSION, FLAG_REPLY,
                                        key, self.node_id, msg_id,
                                        len(frame) - HEADER_NBYTES)
                self.endpoint.send(src, frame)
        finally:
            _current_node.reset(token)

    # -- event loop -----------------------------------------------------------

    def run(self, poll_timeout: float = 0.1) -> None:
        while not self._stop.is_set():
            frame = self.endpoint.recv(timeout=poll_timeout)
            if frame is not None:
                self._handle_frame(frame)

    def start(self) -> "NodeRuntime":
        if self.inline:
            raise OffloadError("inline runtimes poll from the caller thread")
        self._thread = threading.Thread(
            target=self.run, name=f"ham-node-{self.node_id}", daemon=True
        )
        self._thread.start()
        return self

    def request_stop(self) -> None:
        self._stop.set()

    def stop(self, timeout: float = 5.0) -> None:
        self.request_stop()
        if self._thread is not None:
            self._thread.join(timeout)
        n = self.futures.fail_all(NodeDownError(f"node {self.node_id} stopped"))
        if n:
            self.stats["errors"] += n
