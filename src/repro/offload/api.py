"""HAM-Offload public API (paper §2, Fig. 2).

``OffloadDomain`` owns one fabric's worth of nodes and exposes the paper's
surface::

    dom = OffloadDomain.local(num_nodes=2)     # threads-as-nodes
    ptr = dom.allocate(target, (1024,), "float64")
    dom.put(host_array, ptr)
    fut = dom.async_(target, f2f(inner_prod, a_ptr, b_ptr, n))
    c = fut.get()
    dom.shutdown()

Arbitrary offload patterns are supported: host->worker, worker->host
(*reverse offload*, via :func:`current_node` + ``send_async`` from inside a
handler), worker->worker, and one-hop relayed sends (*offload over fabric*).
"""

from __future__ import annotations

import numpy as np

from repro.comm.base import Fabric
from repro.comm.local import LocalFabric
from repro.core.closure import Function, f2f
from repro.core.errors import OffloadError
from repro.core.executor import DirectPolicy
from repro.core.future import _UNSET, Future, as_completed, gather
from repro.core.message import encode_frame, FLAG_DYNAMIC, FLAG_STATIC
from repro.core.registry import default_registry
from repro.offload.buffer import BufferPtr
from repro.offload.runtime import NodeRuntime, current_node


def deref(ptr: BufferPtr) -> np.ndarray:
    """Dereference a buffer pointer on its owning node (handler-side)."""
    return current_node().buffers.deref(ptr)


class OffloadDomain:
    """Host-side view of a set of offload targets."""

    def __init__(
        self,
        fabric: Fabric,
        *,
        host_node: int = 0,
        registry=None,
        inline_host: bool = False,
        policy_factory=DirectPolicy,
        direct_data_plane: bool = True,
        default_timeout: float | None = 30.0,
    ):
        self.fabric = fabric
        self.host_node = host_node
        #: default deadline for the blocking surface (sync/ping/barrier):
        #: a lost reply raises a diagnosis instead of blocking forever
        #: (docs/failure-model.md).  ``None`` = wait forever.
        self.default_timeout = default_timeout
        self.registry = registry or default_registry()
        table = self.registry.table  # must be init()ed by caller (paper §5.2)
        self.host = NodeRuntime(
            host_node, fabric.endpoint(host_node), table, inline=inline_host
        )
        if not inline_host:
            self.host.start()
        self._local_workers: list[NodeRuntime] = []
        self._policy_factory = policy_factory
        self._table = table
        #: same-address-space shortcut for put/get (paper §4.1 / the SCIF
        #: pre-mapped-window analogue): when the target node's runtime lives
        #: in THIS process, the data plane does direct loads/stores on the
        #: buffer instead of a wire round trip — one memcpy total.  Caveat:
        #: a direct put/get is NOT ordered behind still-queued async offloads
        #: to that node (the wire path is); callers needing that ordering
        #: sync on their futures first or pass ``direct_data_plane=False``.
        self.direct_data_plane = direct_data_plane
        self._inproc: dict[int, NodeRuntime] = {host_node: self.host}

    # -- construction helpers -------------------------------------------------

    @staticmethod
    def local(num_nodes: int, *, registry=None, inline_host: bool = False,
              policy_factory=DirectPolicy) -> "OffloadDomain":
        """All nodes in-process (threads) — intra-node offload."""
        fabric = LocalFabric(num_nodes)
        dom = OffloadDomain(
            fabric,
            registry=registry,
            inline_host=inline_host,
            policy_factory=policy_factory,
        )
        for node_id in range(num_nodes):
            if node_id != dom.host_node:
                worker = NodeRuntime(
                    node_id,
                    fabric.endpoint(node_id),
                    dom._table,
                    policy=policy_factory(),
                )
                worker.start()
                dom._local_workers.append(worker)
                dom._inproc[node_id] = worker
        return dom

    @property
    def num_nodes(self) -> int:
        return self.fabric.num_nodes

    def targets(self) -> list[int]:
        # fabric.nodes() rather than range(): elastic fabrics have holes
        # after remove_node, and retired ids must not be addressed
        return [n for n in self.fabric.nodes() if n != self.host_node]

    # -- RPC surface ------------------------------------------------------------

    def async_(self, node: int, function: Function) -> Future:
        """``offload::async`` — returns a future for the remote result."""
        return self.host.send_async(node, function)

    def sync(self, node: int, function: Function, timeout=_UNSET):
        """Blocking call; ``timeout`` omitted => :attr:`default_timeout`
        (``None`` = wait forever)."""
        if timeout is _UNSET:
            timeout = self.default_timeout
        return self.host.send_sync(node, function, timeout)

    def oneway(self, node: int, function: Function) -> None:
        self.host.send_oneway(node, function)

    def relay(self, via: int, dst: int, function: Function) -> Future:
        """Offload over fabric: request travels host -> via -> dst; the reply
        returns directly dst -> host (inner header keeps the origin)."""
        msg_id, fut = self.host.futures.create()
        key = self._table.key_of(function.record.stable_name)
        inner = encode_frame(
            key,
            function.pack_payload(),  # pack_static == WirePlan layout
            src_node=self.host_node,
            msg_id=msg_id,
            flags=FLAG_STATIC if function.is_static else FLAG_DYNAMIC,
        )
        self.host.send_oneway(via, f2f("_ham/forward", dst, bytes(inner),
                                       registry=self.registry))
        return fut

    # -- data plane (paper Fig. 2: allocate/put/get) -----------------------------

    def allocate(self, node: int, shape, dtype) -> BufferPtr:
        tag, n, handle, nbytes = self.sync(
            node,
            f2f("_ham/alloc", list(int(d) for d in shape), str(np.dtype(dtype)),
                registry=self.registry),
        )
        assert tag == "ptr"
        return BufferPtr(n, handle, nbytes)

    #: default transfer segment: put payloads above this are split into
    #: pipelined chunks, so transfers (a) always fit the shm ring window
    #: regardless of buffer size and (b) overlap the sender's pack-copy with
    #: the receiver's buffer-copy (measured ~5x on 64 MB puts).  Must fit the
    #: transport frame limit (shm ring capacity, default 16 MB); smaller
    #: chunks trade pipelining gain for per-segment round-trip overhead.
    chunk_nbytes: int = 8 << 20

    def put(self, src: np.ndarray, ptr: BufferPtr, *, offset: int = 0,
            chunk_nbytes: int | None = None) -> None:
        if self.direct_data_plane:
            rt = self._inproc.get(ptr.node)
            if rt is not None:  # direct store into the pre-mapped buffer

                def _store():
                    flat = rt.buffers.flat(ptr)
                    src_flat = np.ascontiguousarray(src).reshape(-1)
                    flat[offset : offset + src_flat.size] = src_flat.astype(
                        flat.dtype, copy=False
                    )

                self._run_direct(_store)
                return
        arr = np.ascontiguousarray(src)
        limit = self.chunk_nbytes if chunk_nbytes is None else chunk_nbytes
        # clamp to what the transport can move in one frame (shm ring size),
        # leaving headroom for the frame header + TLV prefix
        cap = getattr(self.host.endpoint, "max_frame_nbytes", None)
        if limit and cap:
            limit = min(limit, cap - 4096)
        if not limit or arr.nbytes <= limit:
            self.sync(
                ptr.node,
                f2f("_ham/put", ptr.node, ptr.handle, int(offset), arr,
                    registry=self.registry),
            )
            return
        # chunked pipeline: every segment is a zero-copy slice of `arr`,
        # packed straight into its frame; all segments are in flight at once
        flat = arr.reshape(-1)
        step = max(1, limit // arr.dtype.itemsize)
        futs = [
            self.async_(
                ptr.node,
                f2f("_ham/put", ptr.node, ptr.handle, int(offset + o),
                    flat[o : o + step], registry=self.registry),
            )
            for o in range(0, flat.size, step)
        ]
        self._wait_all(futs)

    def chain_put(self, src: np.ndarray, ptr: BufferPtr, hops, dirty: int,
                  *, offset: int = 0, chunk_nbytes: int | None = None,
                  timeout: float | None = 60.0) -> list[int]:
        """Chain-replicated put (``repro.offload.dataplane``, "Chain
        replication"): the payload travels host -> ``ptr.node`` ONCE, as
        the same pipelined chunk stream as :meth:`put`, and ``ptr.node``
        forwards each chunk down ``hops`` over worker->worker links while
        the next chunk is still in flight.  ``dirty`` is the write epoch
        minted by ``BufferDirectory.begin_write``.  Returns the node ids
        that confirmed the COMPLETE write, primary first — a truncated
        list names exactly the stale tail.

        When every holder is in-process (``direct_data_plane``, thread
        workers) the chain degenerates to direct stores — the bytes are
        already in shared memory, so copying host -> each holder is
        strictly cheaper than framing a wire chain.  Otherwise the wire
        path runs: the chain forwarding executes in the primary's handler
        context."""
        arr = np.ascontiguousarray(src)
        hops = [int(h) for h in hops]
        if self.direct_data_plane:
            holders = [int(ptr.node), *hops]
            rts = [self._inproc.get(n) for n in holders]
            if all(rt is not None for rt in rts):
                src_flat = arr.reshape(-1)

                def _store():
                    for n, rt in zip(holders, rts):
                        flat = rt.buffers.flat(ptr.at(n))
                        flat[offset : offset + src_flat.size] = \
                            src_flat.astype(flat.dtype, copy=False)
                        rt.applied_dirty[int(ptr.handle)] = int(dirty)

                self._run_direct(_store)
                return holders
        limit = self.chunk_nbytes if chunk_nbytes is None else chunk_nbytes
        cap = getattr(self.host.endpoint, "max_frame_nbytes", None)
        if limit and cap:
            limit = min(limit, cap - 4096)
        flat = arr.reshape(-1)
        step = max(1, limit // max(1, arr.dtype.itemsize)) if limit \
            else max(1, flat.size)
        futs = []
        nchunks = 0
        if flat.size:
            futs = [
                self.async_(
                    ptr.node,
                    f2f("_ham/chain_put", int(ptr.handle), int(offset + o),
                        flat[o : o + step], hops, int(dirty),
                        registry=self.registry),
                )
                for o in range(0, flat.size, step)
            ]
            nchunks = len(futs)
        # the flush rides the same pipeline (per-link FIFO orders it behind
        # every chunk) — no extra round trip after the last chunk ack
        flush = self.async_(
            ptr.node,
            f2f("_ham/chain_flush", int(ptr.handle), hops, int(dirty),
                int(nchunks), registry=self.registry),
        )
        results = self._wait_all([*futs, flush], timeout)
        return [int(n) for n in results[-1]]

    def get(self, ptr: BufferPtr, *, offset: int = 0, count: int = -1,
            chunk_count: int | None = None) -> np.ndarray:
        """Fetch ``count`` elements from ``offset`` (whole, shaped buffer when
        ``count < 0``).  ``chunk_count`` (elements per segment) opts into a
        chunked, pipelined fetch — required when the flat reply would exceed
        the transport frame limit; the segments are reassembled host-side."""
        if self.direct_data_plane:
            rt = self._inproc.get(ptr.node)
            if rt is not None:  # direct load from the pre-mapped buffer

                def _load():
                    if count < 0 and not offset:
                        return rt.buffers.deref(ptr).copy()
                    flat = rt.buffers.flat(ptr)
                    view = (flat[offset:] if count < 0
                            else flat[offset : offset + count])
                    return view.copy()

                return self._run_direct(_load)
        if chunk_count and count >= 0 and count > chunk_count:
            futs = [
                self.async_(
                    ptr.node,
                    f2f("_ham/get", ptr.node, ptr.handle, int(offset + o),
                        int(min(chunk_count, count - o)),
                        registry=self.registry),
                )
                for o in range(0, count, chunk_count)
            ]
            chunks = self._wait_all(futs)
            out = np.empty(count, dtype=chunks[0].dtype)
            o = 0
            for c in chunks:
                out[o : o + c.size] = c
                o += c.size
            return out
        return self.sync(
            ptr.node,
            f2f("_ham/get", ptr.node, ptr.handle, int(offset), int(count),
                registry=self.registry),
        )

    @staticmethod
    def _run_direct(op):
        """Run a direct data-plane operation, surfacing every failure (bad
        handle, out-of-range slice, dtype mismatch) exactly as the wire path
        would — RemoteExecutionError — so callers see one error contract
        regardless of which plane served them."""
        try:
            return op()
        except Exception as e:  # noqa: BLE001 — mirror the remote-error wrap
            from repro.core.errors import RemoteExecutionError

            raise RemoteExecutionError(f"{type(e).__name__}: {e}", "") from e

    def _wait_all(self, futs: list[Future], timeout: float | None = 60.0) -> list:
        """Results in submission order, waited in *completion* order: one
        shared deadline over the whole pipelined batch (chunked put/get,
        barriers) rather than a fresh timeout per future."""
        if self.host.inline:
            return [self.host._inline_wait(f, timeout) for f in futs]
        return gather(futs, timeout)

    def free(self, ptr: BufferPtr) -> None:
        self.sync(ptr.node, f2f("_ham/free", ptr.node, ptr.handle,
                                registry=self.registry))

    # -- control ------------------------------------------------------------------

    def ping(self, node: int, token: int = 0, timeout=_UNSET):
        if timeout is _UNSET:
            timeout = (10.0 if self.default_timeout is None
                       else min(10.0, self.default_timeout))
        return self.sync(node, f2f("_ham/ping", int(token),
                                   registry=self.registry), timeout)

    def barrier(self, timeout=_UNSET) -> None:
        if timeout is _UNSET:
            timeout = self.default_timeout
        futs = [
            self.async_(n, f2f("_ham/ping", 0, registry=self.registry))
            for n in self.targets()
        ]
        self._wait_all(futs, timeout)

    def shutdown(self, timeout: float = 5.0) -> None:
        for n in self.targets():
            try:
                self.oneway(n, f2f("_ham/terminate", registry=self.registry))
            except Exception:  # noqa: BLE001 — best-effort on teardown
                pass
        for w in self._local_workers:
            w.stop(timeout)
        self.host.stop(timeout)
        self.fabric.close()


def offloaded(*example_args, registry=None, name=None):
    """Decorator: register a function as an offload target with a static
    spec derived from example arguments (the ``Pars...``)."""

    def wrap(fn):
        reg = registry or default_registry()
        reg.handler(fn, args=example_args, name=name)
        return fn

    return wrap
