"""Location-transparent buffer namespace: directory, epochs, replication.

HAM's address-translation layer made *handlers* location-transparent; this
module does the same for the *data plane*.  The design follows HPX's AGAS
(global ids decoupled from placement — Heller et al.) and Active Access
(Besta & Hoefler: the runtime, not the caller, resolves where data lives):

* A buffer's identity is its **global handle** (``BufferPtr.handle``,
  minted node-namespaced so it is unique cluster-wide and survives any
  move).  The pointer's ``node`` field is only a placement *hint*.
* The host-side :class:`BufferDirectory` is the source of truth: it maps
  ``handle -> (primary, replicas, epoch, shape/dtype, session)``.
* The **ownership epoch** makes hints safely cacheable: every primary move
  bumps the buffer's epoch, so a pointer carrying an older epoch is *stale*
  and is transparently rewritten by :meth:`BufferDirectory.resolve` /
  :meth:`resolve_args` — a current-epoch pointer skips the directory.

Ownership / epoch / replication protocol
----------------------------------------

``allocate`` (through :class:`~repro.cluster.pool.ClusterPool`):
  the primary node mints the handle and zero-fills the array; each of the
  ``replicas=N`` holder nodes installs an empty copy under the SAME handle
  via ``_ham/buf_adopt``; the directory records the set at epoch 0.

``put`` (chain-replicated write-through):
  the host sends the payload ONCE — to the primary, over the existing
  zero-copy chunked path — and the primary streams it on to the replicas
  over worker->worker links (the chain-replication write protocol below),
  so copies never diverge and promotion needs no data movement, without
  the host paying one wire transfer per holder.

**Crash** (pool monitor announces a death):
  :meth:`BufferDirectory.on_node_death` runs *metadata-only* promotion —
  for every buffer whose primary died and that has a live replica, the
  lowest-id replica becomes primary and the epoch bumps; a buffer with no
  replica is recorded **lost** (later resolves raise, they do not hang).
  Sessions bound to moved buffers are re-pinned onto the node now holding
  their bytes (``on_repin`` hooks — the scheduler's SessionRouter
  subscribes), so a dead worker's sessions resume WITH their data.

**Drain shrink** (``ClusterPool.remove_node(drain=True)``):
  before the scheduler fence, every primary on the leaving node is migrated
  — promoted in place when a replica already holds the bytes (zero copy),
  else streamed to a survivor via adopt + chunked put — and every replica
  it held is backfilled elsewhere; each move bumps the epoch.  Shrink is
  lossless by construction.

**Join** (``ClusterPool.add_node``):
  lazy backfill — buffers left under-replicated by earlier deaths copy one
  replica onto the joiner.

**Free / session end**:
  freeing anywhere frees the logical buffer everywhere: the directory drops
  the record and every other holder gets ``_ham/buf_invalidate`` (idempotent
  discard), so ``live_count`` stays truthful cluster-wide and replicas do
  not leak when a session completes.  A worker-side ``_ham/free`` announces
  itself to the host with a ``_ham/buf_freed`` oneway for the same reason.

Stale-pointer re-resolution happens at the *submit boundary* (the
scheduler rewrites ``BufferPtr`` args against the directory and may
retarget them at any live holder it routes to), so handler code and the
per-node :class:`~repro.offload.buffer.BufferRegistry` keep the paper's
strict own-address-space dereference rule.

Directory gossip / durable directory (host crash recovery)
----------------------------------------------------------

The directory is host-side state — and PR 5 made every *worker* crash
recoverable, which left the host as the last unprotected failure domain: a
host crash used to take the placement map (and with it every tracked
buffer) down even though the bytes were still sitting in worker memory.
The durable-directory protocol journals the map to its own data:

* **Journal (gossip-out)**: every directory mutation fires ``on_change``
  hooks outside the lock; the pool subscribes and pushes the updated record
  to each *holder* of the buffer as a ``_ham/dir_gossip`` oneway.  A worker
  keeps only the shard of directory state for buffers it holds
  (``NodeRuntime.dir_shard``) — per-worker memory is proportional to the
  worker's own data, not the cluster's.  Entries are installed
  epoch-monotonically (``>=`` — holder-set changes do not bump epochs, and
  per-link FIFO orders same-epoch updates); a tombstone (``primary < 0``,
  sent on free/lost) or an entry that no longer names the worker as holder
  deletes the shard entry.
* **Rebuild (gossip-in)**: ``ClusterPool.restart_host`` replaces the host
  runtime, then sync-calls ``_ham/dir_dump`` on every survivor and merges
  the shards — highest epoch wins, ties prefer the entry whose dumper is
  its own primary (a holder always has the freshest view of a buffer it
  serves).  An entry whose primary did not survive promotes onto its
  lowest live replica (epoch + 1, exactly the crash-promotion rule); an
  entry with no live holder is recorded lost.  The merged set is
  :meth:`BufferDirectory.install`-ed into a fresh directory without
  re-firing the hooks (the state *came from* the shards).
* **Guarantee**: gossip oneways are best-effort, but a lost gossip frame
  can only lose *metadata newer than the bytes' placement changed* — and
  placement changes are host-driven, so the host that crashed was the only
  writer.  Any buffer whose holders survive the host crash is recoverable;
  ``BENCH_cluster.json`` ``recovery.host_restart`` asserts ``lost = 0``.

Chain replication (the write protocol)
--------------------------------------

Contract: docs/failure-model.md, "Write visibility and convergence".

A replicated write moves bytes exactly once per link: host -> primary ->
replica 1 -> replica 2 -> ...  Three handlers implement it:

* ``_ham/chain_put(handle, offset, chunk, hops, dirty)`` — store one chunk
  locally, then forward it to ``hops[0]`` (with ``hops[1:]``) as a
  *oneway*, pushed onto the wire before the next inbound chunk is handled,
  so chunk k travels down-chain while chunk k+1 is still arriving
  (pipelining — the chain costs ~one link of latency, not one transfer
  per holder).  Forwards deliberately carry no reply: a handler blocking
  on per-chunk acks can deadlock against its own event loop's drain batch
  (an ack drained *behind* the blocking frame is unreachable), and the
  flush's chunk count subsumes them.
* ``_ham/chain_flush(handle, hops, dirty, nchunks)`` — the write's tail:
  verify all ``nchunks`` chunks of write epoch ``dirty`` landed here
  (per-link FIFO puts the flush behind every forwarded chunk), record
  ``applied_dirty[handle] = dirty`` (this node's bytes now reflect that
  write), then flush the rest of the chain synchronously.  Returns the
  list of node ids that confirmed the complete write — a crash or
  partition mid-chain truncates the list at the break, never hides it.
* ``_ham/chain_push(handle, hops, dirty, chunk_nbytes, adopt)`` — the
  source-driven form (migration, backfill, post-mutation refresh): the
  node holding the bytes streams its own copy down ``hops`` with a bounded
  send window, no host staging.

Sequencing: every write carries a **dirty epoch** minted by the host
directory (:meth:`BufferDirectory.begin_write` — distinct from the
*ownership* epoch, which tracks placement).  A holder's ``applied_dirty``
is dumped next to its shard entry (``_ham/dir_dump``), so a host rebuild
can detect a chain tail that missed a write (its applied epoch trails a
surviving peer's) and drop it from the promotable set — a crash mid-chain
leaves a *detectable* stale tail, healed by the ordinary promotion and
lazy-backfill machinery, never a silently promotable stale copy.

Read-only routing contract (what keeps copies from diverging)
-------------------------------------------------------------

Chain-replicated ``put`` and declared-``mutates`` handler commits (below)
are the only sanctioned ways to change a replicated buffer's bytes.  An
*undeclared* handler write through ``deref`` updates exactly one copy — so
serving such a call from a replica would silently diverge it from the
primary, and a later crash could promote either version.  The guard is
declarative: only handlers registered with ``read_only=True``
(:class:`~repro.core.registry.HandlerRecord`) may have their pointers
retargeted at a replica holder or widen their locality votes to every
holder; every other call has its pointers pinned to the *primary* (and
votes for the primary only), so an undeclared mutation can only ever land
on the authoritative copy.  Replica-routed reads additionally **fence on
the write epoch**: while a chain write is in flight
(:meth:`BufferDirectory.writing`), reads pin to the primary instead of a
replica whose bytes are mid-overwrite.

Mutate-at-data (Active Access writes)
-------------------------------------

A handler registered ``mutates=True`` is the declared write-side twin of
``read_only``: the scheduler routes it to the primary, lets it mutate the
authoritative bytes in place (the operation ships to the data — no
get/mutate/put round trip), and **commits** the mutation afterwards:
:meth:`BufferDirectory.commit_write` bumps the buffer's dirty epoch and
the pool either *invalidates* the replica holders (they drop their copy
and re-backfill lazily — the default, metadata-only) or *refreshes* them
(the primary chain-pushes the new bytes down the same chain).  Either way
no reader can silently observe a pre-mutation replica after the commit:
the copy is gone from the holder set, or it holds the new bytes.  The
bare primitive — route at primary, execute, commit, nothing queued in
between — is ``ClusterPool.mutate``; the scheduler path layers
deadlines/retries on the same contract for scheduled traffic.  A
handler that is *neither* ``read_only`` nor ``mutates`` and derefs a
replicated buffer gets a one-shot warning pointing at the contract
(docs/failure-model.md) — its in-place writes are invisible to replicas
until the caller re-puts.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Hashable

from repro.core.errors import OffloadError, RegistrySealedError
from repro.core.migratable import MAX_SCAN_DEPTH
from repro.offload.buffer import BufferPtr


@dataclasses.dataclass
class BufferRecord:
    """Directory entry: current placement of one logical buffer."""

    handle: int
    primary: int
    replicas: tuple[int, ...]
    epoch: int
    nbytes: int
    shape: tuple
    dtype: str
    session: Hashable | None = None
    #: write (dirty) epoch — bumped per committed write/mutation, sequenced
    #: by the directory (module docs, "Chain replication").  Orthogonal to
    #: ``epoch``, which tracks *placement* (primary moves).
    dirty: int = 0

    @property
    def holders(self) -> tuple[int, ...]:
        return (self.primary, *self.replicas)

    def ptr(self) -> BufferPtr:
        return BufferPtr(self.primary, self.handle, self.nbytes, self.epoch)


class BufferDirectory:
    """Host-side id -> (primary, replicas, epoch) map with stale-pointer
    resolution and crash promotion (protocol in the module docstring).

    Thread-safe; promotion runs on the pool monitor thread and is metadata
    only (the replica already holds the bytes).  ``on_repin`` hooks fire
    outside the lock with ``(session_key, new_node)`` whenever a primary
    move strands a session's pin — the scheduler's SessionRouter subscribes
    and moves the session to its data.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._records: dict[int, BufferRecord] = {}
        self._lost: dict[int, str] = {}  # handle -> why
        #: handles with a chain write in flight (begin_write .. commit_write)
        #: — replica-routed reads fence on this (module docs)
        self._writing: dict[int, int] = {}
        self._repin_hooks: list[Callable[[Hashable, int], None]] = []
        #: gossip journal subscribers (module docs, durable directory):
        #: cb(handle, record_snapshot_or_None, holders_to_notify)
        self._change_hooks: list[Callable] = []
        self.stats = {"promoted": 0, "lost": 0, "migrated": 0,
                      "backfilled": 0, "stale_resolved": 0, "freed": 0}

    # -- registration ------------------------------------------------------

    def register(self, ptr: BufferPtr, shape, dtype,
                 replicas=(), session: Hashable | None = None) -> BufferPtr:
        rec = BufferRecord(
            handle=ptr.handle, primary=ptr.node,
            replicas=tuple(int(r) for r in replicas), epoch=0,
            nbytes=ptr.nbytes, shape=tuple(int(d) for d in shape),
            dtype=str(dtype), session=session,
        )
        with self._lock:
            self._records[ptr.handle] = rec
        self._fire_change(ptr.handle, rec, rec.holders)
        return rec.ptr()

    def on_repin(self, cb: Callable[[Hashable, int], None]) -> None:
        self._repin_hooks.append(cb)

    def on_change(self, cb: Callable) -> None:
        """Subscribe to the directory journal: ``cb(handle, record, holders)``
        after every mutation, OUTSIDE the lock — ``record`` is a snapshot
        (None = the buffer is gone: freed or lost) and ``holders`` names the
        nodes whose gossip shard the change concerns (for a tombstone, the
        *previous* holders).  The pool's gossip fan-out subscribes here."""
        self._change_hooks.append(cb)

    def _fire_change(self, handle: int, rec: BufferRecord | None,
                     holders) -> None:
        if not self._change_hooks:
            return
        snap = None if rec is None else dataclasses.replace(rec)
        for cb in self._change_hooks:
            try:
                cb(int(handle), snap, tuple(holders))
            except Exception:  # noqa: BLE001 — a bad journal subscriber must
                # not block the mutation (gossip is best-effort by contract)
                import traceback

                traceback.print_exc()

    def install(self, records, lost: dict[int, str] | None = None) -> None:
        """Bulk-install ``records`` (host-crash rebuild from worker shards —
        module docs): replaces the tracked set; ``lost`` maps handles that
        did not survive to their diagnosis (resolves raise it).  Does NOT
        fire change hooks: the installed state came *from* the shards,
        re-gossiping it would be a no-op round trip."""
        with self._lock:
            self._records = {int(r.handle): r for r in records}
            if lost:
                self._lost.update({int(h): str(w) for h, w in lost.items()})
                self.stats["lost"] += len(lost)

    # -- lookup / resolution -----------------------------------------------

    def __len__(self) -> int:
        return len(self._records)

    def empty(self) -> bool:
        """True when a submit-path resolution pass cannot possibly matter:
        nothing tracked AND nothing lost (a lost handle must still raise)."""
        return not self._records and not self._lost

    def lookup(self, handle: int) -> BufferRecord | None:
        """Snapshot of a buffer's current record (promotion/migration keep
        mutating the live entry — callers get a stable copy)."""
        with self._lock:
            rec = self._records.get(int(handle))
            return None if rec is None else dataclasses.replace(rec)

    def lost_reason(self, handle: int) -> str | None:
        with self._lock:
            return self._lost.get(int(handle))

    def resolve(self, ptr: BufferPtr) -> BufferPtr:
        """Current pointer for ``ptr``'s buffer.  A stale epoch is rewritten
        to the live primary; an unknown handle passes through untouched (the
        directory only speaks for buffers it registered); a lost buffer
        raises — callers get a diagnosis, not a dangling-handle error on
        some arbitrary node."""
        with self._lock:
            rec = self._records.get(ptr.handle)
            if rec is None:
                why = self._lost.get(ptr.handle)
                if why is not None:
                    raise OffloadError(
                        f"buffer {ptr.handle:#x} lost: {why} (no replica held "
                        "its bytes; allocate with replicas>=1 to survive a "
                        "crash)"
                    )
                return ptr
            if ptr.epoch == rec.epoch and ptr.node == rec.primary:
                return ptr
            self.stats["stale_resolved"] += 1
            return rec.ptr()

    def resolve_args(self, args, target: int | None = None):
        """Rewrite every ``BufferPtr`` in a shallow pytree of call args.

        Each pointer resolves to its *current* placement; when ``target`` is
        given and holds a copy (primary OR replica), the hint is retargeted
        at ``target`` so the receiving node's own-address-space dereference
        check passes — this is what lets locality routing serve a read from
        any live replica.  Callers must only pass ``target`` for calls
        declared ``read_only`` (module docs, read-only routing contract);
        with ``target=None`` every pointer pins to the primary.  Returns
        ``(new_args, changed)``; the original structure is returned
        untouched when nothing needed rewriting.

        Containers are descended to the same depth-32 bound
        ``scan_locality`` enforces (``migratable.MAX_SCAN_DEPTH``) — a
        pointer deep enough to vote is always deep enough to rewrite, so
        locality routing can never ship a frame whose hint fails the
        holder's own-address-space check.
        """

        def walk(v, depth=0):
            if isinstance(v, BufferPtr):
                rec = self.lookup(v.handle)
                if rec is None:
                    return self.resolve(v)  # raises for lost buffers
                # replica-read fence: while a chain write is in flight the
                # replica's bytes are mid-overwrite — pin to the primary
                node = target if (target is not None and target in rec.holders
                                  and not self.writing(v.handle)) \
                    else rec.primary
                if v.node == node and v.epoch == rec.epoch:
                    return v
                self.stats["stale_resolved"] += v.epoch != rec.epoch
                return v.at(node, rec.epoch)
            if depth >= MAX_SCAN_DEPTH:  # same bound as scan_locality's walk
                return v
            if isinstance(v, (list, tuple)):
                out = [walk(i, depth + 1) for i in v]
                if all(a is b for a, b in zip(out, v)):
                    return v
                return type(v)(out)
            if isinstance(v, dict):
                out = {k: walk(i, depth + 1) for k, i in v.items()}
                if all(out[k] is v[k] for k in v):
                    return v
                return out
            return v

        new = tuple(walk(a) for a in args)
        changed = any(a is not b for a, b in zip(new, args))
        return (new if changed else tuple(args)), changed

    def locality_resolver(self, value):
        """``scan_locality`` resolver for READ-ONLY calls: a registered
        buffer votes for EVERY live holder (any copy can serve a read),
        nbytes-weighted; unknown values fall back to the codec's
        single-node hint (return None).  Calls not declared read-only must
        use :meth:`primary_resolver` instead — routing a mutating call at
        a replica would diverge the copies (module docs)."""
        if not isinstance(value, BufferPtr):
            return None
        rec = self.lookup(value.handle)
        if rec is None:
            return None
        w = max(1, rec.nbytes)
        if self.writing(value.handle):
            # replica-read fence (module docs): votes narrow to the primary
            # so _resolve_for's pin and the routing choice agree
            return {rec.primary: w}
        return {n: w for n in rec.holders}

    def primary_resolver(self, value):
        """``scan_locality`` resolver for calls NOT declared read-only: a
        registered buffer votes for its current *primary* only (fixing a
        stale ``ptr.node`` hint in passing); unknown values fall back to
        the codec (return None)."""
        if not isinstance(value, BufferPtr):
            return None
        rec = self.lookup(value.handle)
        if rec is None:
            return None
        return {rec.primary: max(1, rec.nbytes)}

    # -- write sequencing (dirty epochs; module docs, chain replication) ----

    def begin_write(self, handle: int) -> int:
        """Open a chain write: mint and return the buffer's next dirty
        epoch.  No gossip fires here — the bytes are not anywhere yet; the
        matching :meth:`commit_write` journals the final state.  While open,
        replica-routed reads fence to the primary (:meth:`writing`)."""
        with self._lock:
            rec = self._records[int(handle)]
            rec.dirty += 1
            self._writing[int(handle)] = self._writing.get(int(handle), 0) + 1
            return rec.dirty

    def commit_write(self, handle: int, stale=()) -> BufferRecord | None:
        """Close a chain write: drop the ``stale`` holders (replicas that
        did not confirm the write — a copy that may be torn must never be
        promotable) and fire ONE gossip journal entry carrying the new
        dirty epoch.  Returns a snapshot of the committed record (None if
        the buffer was freed mid-write)."""
        handle = int(handle)
        with self._lock:
            n = self._writing.get(handle, 0) - 1
            if n > 0:
                self._writing[handle] = n
            else:
                self._writing.pop(handle, None)
            rec = self._records.get(handle)
            if rec is None:
                return None
            dropped = [int(r) for r in stale if r in rec.replicas]
            if dropped:
                rec.replicas = tuple(
                    r for r in rec.replicas if r not in dropped
                )
            holders = (*rec.holders, *dropped)
            snap = dataclasses.replace(rec)
        # dropped holders are notified too: their shard entry must go
        self._fire_change(handle, rec, holders)
        return snap

    def writing(self, handle: int) -> bool:
        """True while a chain write to ``handle`` is in flight — the
        replica-read fence (module docs, read-only routing contract)."""
        with self._lock:
            return int(handle) in self._writing

    # -- placement mutation (epoch bumps) ----------------------------------

    def set_primary(self, handle: int, node: int) -> BufferPtr:
        """Move a buffer's primary (drain migration); bumps the epoch."""
        changed = False
        with self._lock:
            rec = self._records[int(handle)]
            if node != rec.primary:
                rec.replicas = tuple(
                    r for r in rec.replicas if r != node
                )
                rec.primary, rec.epoch = int(node), rec.epoch + 1
                self.stats["migrated"] += 1
                changed = True
            ptr = rec.ptr()
        if changed:
            self._fire_change(handle, rec, rec.holders)
        return ptr

    def remove_replica(self, handle: int, node: int) -> None:
        """Forget one replica (its copy failed to update or its node is
        unreachable): a holder that may be stale must never be promoted."""
        changed = False
        with self._lock:
            rec = self._records.get(int(handle))
            if rec is not None and node in rec.replicas:
                rec.replicas = tuple(r for r in rec.replicas if r != node)
                changed = True
        if changed:
            # the dropped holder is notified too: its shard entry must go
            self._fire_change(handle, rec, (*rec.holders, int(node)))

    def add_replica(self, handle: int, node: int) -> None:
        changed = False
        with self._lock:
            rec = self._records.get(int(handle))
            if rec is not None and node != rec.primary \
                    and node not in rec.replicas:
                rec.replicas = (*rec.replicas, int(node))
                self.stats["backfilled"] += 1
                changed = True
        if changed:
            self._fire_change(handle, rec, rec.holders)

    def detach_node(self, node: int) -> None:
        """Forget ``node`` as a holder everywhere (it left cleanly; its
        primaries must already have been migrated off)."""
        touched = []
        with self._lock:
            for rec in self._records.values():
                if node in rec.replicas:
                    rec.replicas = tuple(r for r in rec.replicas if r != node)
                    touched.append(rec)
        for rec in touched:
            self._fire_change(rec.handle, rec, rec.holders)

    def primaries_on(self, node: int) -> list[BufferRecord]:
        with self._lock:
            return [dataclasses.replace(r) for r in self._records.values()
                    if r.primary == node]

    def replicas_on(self, node: int) -> list[BufferRecord]:
        with self._lock:
            return [dataclasses.replace(r) for r in self._records.values()
                    if node in r.replicas]

    def under_replicated(self, factor: int, live: set[int]) -> list[BufferRecord]:
        """Records holding fewer than ``factor`` live replicas (join-time
        lazy backfill scans this)."""
        with self._lock:
            return [
                dataclasses.replace(r) for r in self._records.values()
                if len([n for n in r.replicas if n in live]) < factor
            ]

    # -- crash promotion ---------------------------------------------------

    def on_node_death(self, node: int) -> dict[int, int]:
        """Metadata-only failover for every buffer ``node`` held.  Returns
        ``{handle: new_primary}`` for the promoted buffers; buffers with no
        surviving replica are recorded lost.  Fires ``on_repin`` hooks (see
        class docs) after the lock is released."""
        moved: dict[int, int] = {}
        sessions: set = set()
        touched: list = []  # (handle, rec_or_None, holders_to_notify)
        with self._lock:
            for handle, rec in list(self._records.items()):
                if rec.primary == node:
                    live_reps = [r for r in rec.replicas if r != node]
                    if live_reps:
                        rec.primary = min(live_reps)
                        rec.replicas = tuple(
                            r for r in live_reps if r != rec.primary
                        )
                        rec.epoch += 1
                        moved[handle] = rec.primary
                        self.stats["promoted"] += 1
                        touched.append((handle, rec, rec.holders))
                        if rec.session is not None:
                            sessions.add(rec.session)
                    else:
                        del self._records[handle]
                        self._lost[handle] = f"primary node {node} died"
                        self.stats["lost"] += 1
                        touched.append((handle, None, ()))
                elif node in rec.replicas:
                    rec.replicas = tuple(r for r in rec.replicas if r != node)
                    touched.append((handle, rec, rec.holders))
        for handle, rec, holders in touched:
            self._fire_change(handle, rec, holders)
        for key in sessions:
            self._fire_repin(key)
        return moved

    # -- sessions ----------------------------------------------------------

    def bind_session(self, handle: int, session: Hashable) -> None:
        with self._lock:
            rec = self._records.get(int(handle))
            if rec is not None:
                rec.session = session
        if rec is not None:
            self._fire_change(handle, rec, rec.holders)

    def session_records(self, session: Hashable) -> list[BufferRecord]:
        with self._lock:
            return [dataclasses.replace(r) for r in self._records.values()
                    if r.session == session]

    def session_home(self, session: Hashable) -> int | None:
        """Node holding the most bytes of a session's buffers (primary
        placement) — where the session should live."""
        votes: dict[int, int] = {}
        for rec in self.session_records(session):
            votes[rec.primary] = votes.get(rec.primary, 0) + max(1, rec.nbytes)
        if not votes:
            return None
        return max(votes, key=lambda n: (votes[n], -n))

    def _fire_repin(self, session: Hashable) -> None:
        home = self.session_home(session)
        if home is None:
            return
        for cb in self._repin_hooks:
            try:
                cb(session, home)
            except Exception:  # noqa: BLE001 — a bad subscriber must not
                # stop failover for the remaining sessions
                import traceback

                traceback.print_exc()

    def repin_sessions_moved(self, handles) -> None:
        """Fire repin hooks for the sessions of explicitly moved buffers
        (drain migration calls this after its copies land)."""
        sessions = set()
        with self._lock:
            for h in handles:
                rec = self._records.get(int(h))
                if rec is not None and rec.session is not None:
                    sessions.add(rec.session)
        for key in sessions:
            self._fire_repin(key)

    # -- free --------------------------------------------------------------

    def mark_lost(self, handle: int, why: str) -> None:
        """Record a buffer unrecoverable (e.g. its drain-migration copy
        failed and its only holder is being retired): the record is dropped
        and later resolves raise the diagnosis instead of routing at a
        retired node."""
        with self._lock:
            rec = self._records.pop(int(handle), None)
            if rec is not None:
                self._lost[int(handle)] = why
                self.stats["lost"] += 1
        if rec is not None:
            # tombstone to the previous holders: their shard entries must go
            self._fire_change(handle, None, rec.holders)

    def drop(self, handle: int) -> BufferRecord | None:
        """Forget a buffer (it is being freed); returns the final record so
        the caller can invalidate the remaining holders."""
        with self._lock:
            rec = self._records.pop(int(handle), None)
            if rec is not None:
                self.stats["freed"] += 1
        if rec is not None:
            self._fire_change(handle, None, rec.holders)
        return rec

    def live_handles(self) -> list[int]:
        with self._lock:
            return sorted(self._records)

    def lost_handles(self) -> list[int]:
        with self._lock:
            return sorted(self._lost)


def tracked_handles(directory: BufferDirectory, args) -> tuple[int, ...]:
    """Directory-tracked buffer handles referenced by ``args`` — the
    handles a mutating call's commit must invalidate (module docs,
    "Mutate-at-data").  Same shallow pytree walk and depth bound as
    :meth:`BufferDirectory.resolve_args` / ``scan_locality``: a pointer
    deep enough to route on is deep enough to commit."""
    found: list[int] = []

    def walk(v, depth=0):
        if isinstance(v, BufferPtr):
            if directory.lookup(v.handle) is not None:
                found.append(int(v.handle))
            return
        if depth >= MAX_SCAN_DEPTH:
            return
        if isinstance(v, (list, tuple)):
            for i in v:
                walk(i, depth + 1)
        elif isinstance(v, dict):
            for i in v.values():
                walk(i, depth + 1)

    for a in args:
        walk(a)
    return tuple(dict.fromkeys(found))


# --------------------------------------------------------------------------
# control handlers (dynamic payloads; registered at import = static init)
# --------------------------------------------------------------------------


def _h_buf_adopt(handle, shape, dtype):
    """Install an empty copy of a foreign buffer under its global handle
    (replica creation / migration target); the bytes follow over the
    ordinary chunked ``_ham/put`` path."""
    from repro.offload.runtime import current_node

    current_node().buffers.adopt_empty(int(handle), shape, dtype)


def _h_buf_invalidate(handle):
    """Drop this node's copy of a buffer (idempotent — an invalidate may
    race a local free; both outcomes are 'copy gone')."""
    from repro.offload.runtime import current_node

    node = current_node()
    node.dir_shard.pop(int(handle), None)  # gossip hygiene: copy is gone
    return node.buffers.discard(int(handle))


def _h_buf_count():
    """This node's live buffer count — lets tests and benchmarks assert
    cluster-wide replica hygiene (no leaks after free/session end)."""
    from repro.offload.runtime import current_node

    return current_node().buffers.live_count()


def _h_buf_freed(node_id, handle):
    """Host-side half of worker-initiated frees: a worker that freed its
    copy announces it here (oneway); the directory drops the record and the
    remaining holders get ``_ham/buf_invalidate`` oneways, keeping
    ``live_count`` truthful cluster-wide."""
    from repro.core.closure import Function
    from repro.offload.runtime import current_node

    node = current_node()
    directory = getattr(node, "buffer_directory", None)
    if directory is None:
        return
    rec = directory.drop(int(handle))
    if rec is None:  # already dropped (e.g. a host-side free raced us)
        return
    record = node.table.record_of("_ham/buf_invalidate")
    for holder in rec.holders:
        if holder == int(node_id):
            continue  # the announcer already dropped its copy
        try:
            node.send_oneway(holder, Function(record, (int(handle),)))
        except Exception:  # noqa: BLE001 — best effort; the holder may be
            # mid-removal, and a leaked replica is recovered at its teardown
            pass


#: per-hop wait bound for the chain write protocol — how long a node waits
#: on its downstream neighbour before declaring the tail unconfirmable and
#: truncating the confirmation list there (tests shrink this to exercise
#: mid-chain partitions without real-time 30 s stalls)
CHAIN_HOP_TIMEOUT = 30.0

# -- chain replication handlers (module docs, "Chain replication";
# contract in docs/failure-model.md, "Write visibility and convergence") --


def _h_chain_put(handle, offset, chunk, hops, dirty):
    """One chunk of a chain-replicated write: store it locally, then
    forward it to ``hops[0]`` as a *oneway* before returning — chunk k
    rides the next link while chunk k+1 is still arriving here, so the
    whole chain costs ~one link of extra latency, not one transfer per
    holder.  The forward carries no reply on purpose: confirmation flows
    through ``_ham/chain_flush``, which travels the same link (per-link
    FIFO orders it behind every chunk) and checks the receiver's own
    chunk count — waiting on per-chunk acks from handler context can
    deadlock against the event loop's drain batch (an ack drained into
    the same batch *behind* the blocking frame is unreachable until
    timeout)."""
    from repro.core.closure import Function
    from repro.offload.buffer import BufferPtr
    from repro.offload.runtime import current_node

    node = current_node()
    handle, dirty = int(handle), int(dirty)
    flat = node.buffers.flat(BufferPtr(node.node_id, handle))
    n = chunk.size
    flat[offset : offset + n] = chunk.reshape(-1).astype(flat.dtype,
                                                         copy=False)
    seen = node.chain_seen.get(handle)
    if seen is None or seen[0] != dirty:
        node.chain_seen[handle] = seen = [dirty, 0]  # a new write epoch
        # restarts the count; chunks of an abandoned earlier write drop
    seen[1] += 1
    if hops:
        # send_oneway packs (= copies) the chunk into the outbound frame
        # before returning, so a frame-aliasing inbound view is safe here
        record = node.table.record_of("_ham/chain_put")
        node.send_oneway(int(hops[0]), Function(
            record, (handle, int(offset), chunk,
                     [int(h) for h in hops[1:]], dirty)))
        # push the forward out NOW, not at end-of-drain-batch: the next
        # hop must store chunk k while chunk k+1 is still crossing the
        # host->primary link, else the chain serialises into recv-all-
        # then-forward-all and the pipelining win evaporates
        node._flush_egress()


def _h_chain_flush(handle, hops, dirty, nchunks):
    """Tail of one chain write: verify every chunk of write epoch ``dirty``
    landed here, mark this node's bytes as reflecting ``dirty``
    (``applied_dirty``), then flush the rest of the chain synchronously.
    The downstream flush rides the same link as the forwarded chunks, so
    per-link FIFO guarantees the next hop counted every chunk before it
    answers — its own ``got != nchunks`` check subsumes per-chunk acks.
    Returns the node ids holding the COMPLETE write — a crash/partition
    mid-chain truncates the list at the break, so the caller sees exactly
    which tail is stale."""
    from repro.core.closure import Function
    from repro.offload.runtime import current_node

    node = current_node()
    handle, dirty, nchunks = int(handle), int(dirty), int(nchunks)
    seen = node.chain_seen.pop(handle, None)
    got = seen[1] if seen is not None and seen[0] == dirty else 0
    if got != nchunks:
        return []  # torn local copy — and the tail only saw what we forwarded
    node.applied_dirty[handle] = dirty
    if not hops:
        return [node.node_id]
    record = node.table.record_of("_ham/chain_flush")
    try:
        downstream = node.wait(node.send_async(int(hops[0]), Function(
            record, (handle, [int(h) for h in hops[1:]], dirty, nchunks))),
            CHAIN_HOP_TIMEOUT)
    except Exception:  # noqa: BLE001 — next hop unreachable: the chain is
        # confirmed up to and including this node only
        return [node.node_id]
    return [node.node_id, *[int(n) for n in downstream]]


def _h_chain_push(handle, hops, dirty, chunk_nbytes, adopt):
    """Source-driven chain write (migration / backfill / post-mutation
    refresh): stream THIS node's copy of ``handle`` down ``hops`` with a
    bounded send window — the host never stages the bytes.  ``adopt=True``
    first installs an empty copy on each hop (idempotent).  Returns the
    confirmed node ids, exactly as ``_ham/chain_flush``."""
    from repro.core.closure import Function
    from repro.offload.buffer import BufferPtr
    from repro.offload.runtime import current_node

    node = current_node()
    handle, dirty = int(handle), int(dirty)
    hops = [int(h) for h in hops]
    arr = node.buffers.deref(BufferPtr(node.node_id, handle))
    if adopt:
        rec_adopt = node.table.record_of("_ham/buf_adopt")
        for h in hops:
            node.wait(node.send_async(h, Function(
                rec_adopt, (handle, [int(d) for d in arr.shape],
                            str(arr.dtype)))), CHAIN_HOP_TIMEOUT)
    flat = arr.reshape(-1)
    limit = int(chunk_nbytes)
    cap = getattr(node.endpoint, "max_frame_nbytes", None)
    if cap:
        limit = min(limit, cap - 4096)
    step = max(1, limit // max(1, flat.dtype.itemsize))
    rec_put = node.table.record_of("_ham/chain_put")
    window: list = []
    nchunks = 0
    if flat.size:
        for o in range(0, flat.size, step):
            window.append(node.send_async(hops[0], Function(
                rec_put, (handle, int(o), flat[o : o + step], hops[1:],
                          dirty))))
            nchunks += 1
            if len(window) >= 4:  # bounded window: overlap without
                # unbounded frames in flight on a long chain
                node.wait(window.pop(0), CHAIN_HOP_TIMEOUT)
    for fut in window:
        node.wait(fut, CHAIN_HOP_TIMEOUT)
    rec_flush = node.table.record_of("_ham/chain_flush")
    confirmed = node.wait(node.send_async(hops[0], Function(
        rec_flush, (handle, hops[1:], dirty, nchunks))), CHAIN_HOP_TIMEOUT)
    node.applied_dirty[handle] = dirty
    return [node.node_id, *[int(n) for n in confirmed]]


def register_dataplane_handlers(registry=None) -> None:
    """Register the ``_ham/buf_*`` control plane and the ``_ham/chain_*``
    write protocol.  Safe to call repeatedly; silently skipped on an
    already-sealed registry (as with the cluster handlers — then callers
    must have registered these before ``init()``)."""
    from repro.core.registry import default_registry

    # adopt/invalidate/freed mutate the replica map; the chain handlers
    # write buffer bytes; buf_count is a pure read of the local buffer
    # registry (read_only => replica-servable)
    reg = registry or default_registry()
    for name, fn, read_only in (
        ("_ham/buf_adopt", _h_buf_adopt, False),
        ("_ham/buf_invalidate", _h_buf_invalidate, False),
        ("_ham/buf_count", _h_buf_count, True),
        ("_ham/buf_freed", _h_buf_freed, False),
        ("_ham/chain_put", _h_chain_put, False),
        ("_ham/chain_flush", _h_chain_flush, False),
        ("_ham/chain_push", _h_chain_push, False),
    ):
        try:
            reg.register(fn, name=name, read_only=read_only)
        except RegistrySealedError:
            return


register_dataplane_handlers()
