"""Demo/benchmark handlers, importable by worker processes.

Serves as the "same source compiled into every binary" of the paper: host
and workers (forked children or fresh interpreters) import this module, so
all processes derive identical handler keys.
"""

from __future__ import annotations

import numpy as np

from repro.core.migratable import ScalarSpec, spec_of
from repro.core.registry import default_registry
from repro.offload.api import deref

_reg = default_registry()


@_reg.handler(name="demo/empty")
def empty() -> None:
    """The paper's Fig. 3 microbenchmark payload: an empty function."""
    return None


@_reg.handler(name="demo/add")
def add(a, b):
    return a + b


@_reg.handler(name="demo/inner_prod", read_only=True)
def inner_prod(a_ptr, b_ptr, n):
    a = deref(a_ptr)
    b = deref(b_ptr)
    return float(a[:n] @ b[:n])


# saxpy WRITES through y_ptr, so it must not be read_only: the scheduler
# pins its pointers to the primary copy, and the mutation is invisible to
# any replicas until the caller re-puts the buffer (dataplane module docs)
@_reg.handler(name="demo/saxpy")
def saxpy(alpha, x_ptr, y_ptr):
    y = deref(y_ptr)
    y += alpha * deref(x_ptr)
    return None


@_reg.handler(name="demo/matmul")
def matmul(a, b):
    return np.asarray(a) @ np.asarray(b)


# static-spec variant of the empty offload: zero-byte payload AND zero-byte
# static reply (result_specs=()), the true lower bound for dispatch cost
# (key + header only, both directions)
_reg.register(empty, arg_specs=(), result_specs=(), name="demo/empty_static")


def echo_small(a, b, scale, arr):
    """Small-RPC benchmark payload: ~250 B of static args, scalar result."""
    return float(a + b) * scale


#: (i8, i8, f8, 28*f8) = 248 B — the ≤256 B small-call regime of Fig. 3
_ECHO_ARGS = (1, 2, 3.0, np.zeros(28, dtype=np.float64))

# the SAME function on both wire paths, so benchmarks compare mechanism,
# not handler work: _static rides the compiled WirePlan both ways
# (FLAG_STATIC request + plan-packed reply), _dyn rides self-describing TLV
_reg.register(
    echo_small,
    arg_specs=tuple(spec_of(a) for a in _ECHO_ARGS),
    result_specs=(ScalarSpec("f8"),),
    name="demo/echo_small_static",
)
_reg.register(echo_small, name="demo/echo_small_dyn")
