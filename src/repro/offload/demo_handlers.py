"""Demo/benchmark handlers, importable by worker processes.

Serves as the "same source compiled into every binary" of the paper: host
and workers (forked children or fresh interpreters) import this module, so
all processes derive identical handler keys.
"""

from __future__ import annotations

import numpy as np

from repro.core.migratable import ScalarSpec, spec_of
from repro.core.registry import default_registry
from repro.offload.api import deref

_reg = default_registry()


@_reg.handler(name="demo/empty", read_only=True)
def empty() -> None:
    """The paper's Fig. 3 microbenchmark payload: an empty function."""


@_reg.handler(name="demo/add", read_only=True)
def add(a, b):
    return a + b


@_reg.handler(name="demo/inner_prod", read_only=True)
def inner_prod(a_ptr, b_ptr, n):
    a = deref(a_ptr)
    b = deref(b_ptr)
    return float(a[:n] @ b[:n])


# saxpy WRITES through y_ptr — the Active Access mutate-at-data shape:
# declared mutates=True, the scheduler routes the call at y's primary and
# commits the write on completion (dirty epoch bumped, replica holders
# invalidated), so replicas never keep serving the overwritten bytes
# (dataplane module docs; docs/failure-model.md)
@_reg.handler(name="demo/saxpy", mutates=True)
def saxpy(alpha, x_ptr, y_ptr):
    y = deref(y_ptr)
    y += alpha * deref(x_ptr)


@_reg.handler(name="demo/matmul", read_only=True)
def matmul(a, b):
    return np.asarray(a) @ np.asarray(b)


# static-spec variant of the empty offload: zero-byte payload AND zero-byte
# static reply (result_specs=()), the true lower bound for dispatch cost
# (key + header only, both directions)
_reg.register(empty, arg_specs=(), result_specs=(), name="demo/empty_static",
              read_only=True)


def echo_small(a, b, scale, arr):
    """Small-RPC benchmark payload: ~250 B of static args, scalar result."""
    return float(a + b) * scale


#: (i8, i8, f8, 28*f8) = 248 B — the ≤256 B small-call regime of Fig. 3
_ECHO_ARGS = (1, 2, 3.0, np.zeros(28, dtype=np.float64))

# the SAME function on both wire paths, so benchmarks compare mechanism,
# not handler work: _static rides the compiled WirePlan both ways
# (FLAG_STATIC request + plan-packed reply), _dyn rides self-describing TLV
_reg.register(
    echo_small,
    arg_specs=tuple(spec_of(a) for a in _ECHO_ARGS),
    result_specs=(ScalarSpec("f8"),),
    name="demo/echo_small_static",
    read_only=True,
)
_reg.register(echo_small, name="demo/echo_small_dyn", read_only=True)


# -- chaos-suite probes (tests/test_chaos.py; docs/failure-model.md) --------
#
# bump is deliberately MUTATING: the per-token counter is the side-effect
# witness for the exactly-once contract — if a retried call ever
# re-executed, the counter total would exceed the number of logical calls.
# Lives here (not in the test file) so fresh-interpreter socket workers
# import it via the registered-setup-modules path like any demo handler.
# The counter is per PROCESS: thread workers (ClusterPool.local) share one
# — read it from any single node; process workers (shm/socket) each own
# theirs — sum counts over the pool.

_chaos_counters: dict = {}


@_reg.handler(name="chaos/bump", read_only=False)
def chaos_bump(token):
    """Mutating probe: increment this worker's counter for ``token`` and
    return the post-increment value.  Exactly-once under retry means every
    logical call adds exactly 1 to the cluster-wide total."""
    n = _chaos_counters.get(token, 0) + 1
    _chaos_counters[token] = n
    return int(n)


@_reg.handler(name="chaos/counts", read_only=True)
def chaos_counts(token):
    """Read-only probe: this worker's counter for ``token`` (0 if never
    bumped).  Summed across workers to assert zero double-execution."""
    return int(_chaos_counters.get(token, 0))


@_reg.handler(name="chaos/reset", read_only=False)
def chaos_reset(token):
    """Clear this worker's counter for ``token`` (test isolation); returns
    the value it had."""
    return int(_chaos_counters.pop(token, 0))
