"""Demo/benchmark handlers, importable by worker processes.

Serves as the "same source compiled into every binary" of the paper: host
and workers (forked children or fresh interpreters) import this module, so
all processes derive identical handler keys.
"""

from __future__ import annotations

import numpy as np

from repro.core.registry import default_registry
from repro.offload.api import deref

_reg = default_registry()


@_reg.handler(name="demo/empty")
def empty() -> None:
    """The paper's Fig. 3 microbenchmark payload: an empty function."""
    return None


@_reg.handler(name="demo/add")
def add(a, b):
    return a + b


@_reg.handler(name="demo/inner_prod")
def inner_prod(a_ptr, b_ptr, n):
    a = deref(a_ptr)
    b = deref(b_ptr)
    return float(a[:n] @ b[:n])


@_reg.handler(name="demo/saxpy")
def saxpy(alpha, x_ptr, y_ptr):
    y = deref(y_ptr)
    y += alpha * deref(x_ptr)
    return None


@_reg.handler(name="demo/matmul")
def matmul(a, b):
    return np.asarray(a) @ np.asarray(b)


# static-spec variant of the empty offload: zero-byte payload, the true
# lower bound for dispatch cost (key + header only)
_reg.register(empty, arg_specs=(), name="demo/empty_static")
