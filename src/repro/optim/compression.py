"""Error-feedback gradient compression (int8) — a ``migratable``
specialisation in the sense of the paper: a type that cannot be bitwise
copied efficiently (fp32 gradients) gets a serialisation hook that quantises
on encode and dequantises on decode, with the residual kept locally so the
compression error is fed back into the next round (EF-SGD).

Used two ways:
* inside the training step, to halve/quarter the DP all-reduce bytes
  (``compress_tree``/``decompress_tree`` around ``jax.lax.pmean``-equivalent
  collectives — measured in §Perf as collective-term reduction);
* as a HAM message payload (``CompressedTensor`` is registered migratable),
  for the cross-pod asynchronous gradient-exchange example.
"""

from __future__ import annotations

import dataclasses
import struct

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.migratable import register_migratable


# --------------------------------------------------------------------------
# jax-side (in-graph) int8 quantisation with error feedback
# --------------------------------------------------------------------------


def quantize_int8(x):
    """Per-tensor symmetric int8.  Returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, residual):
    """Error-feedback: quantise (g + residual), carry the new residual."""
    def leaf(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return (q, s), x - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    pairs = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    qtree = tdef.unflatten([p[0] for p in pairs])
    new_res = tdef.unflatten([p[1] for p in pairs])
    return qtree, new_res


def ef_decompress_tree(qtree):
    return jax.tree_util.tree_map(
        lambda qs: dequantize_int8(*qs),
        qtree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
    )


def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


# --------------------------------------------------------------------------
# wire-side: CompressedTensor as a migratable type
# --------------------------------------------------------------------------


@dataclasses.dataclass
class CompressedTensor:
    """int8 payload + scale + original shape; 4x smaller than fp32 wire."""

    q: np.ndarray       # int8
    scale: float
    shape: tuple

    @staticmethod
    def compress(x: np.ndarray) -> "CompressedTensor":
        x = np.asarray(x, np.float32)
        amax = float(np.max(np.abs(x))) + 1e-12
        scale = amax / 127.0
        q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
        return CompressedTensor(q.reshape(-1), scale, tuple(x.shape))

    def decompress(self) -> np.ndarray:
        return (self.q.astype(np.float32) * self.scale).reshape(self.shape)

    def encode(self) -> bytes:
        hdr = struct.pack("<dB", self.scale, len(self.shape))
        dims = struct.pack(f"<{len(self.shape)}q", *self.shape)
        return hdr + dims + self.q.tobytes()

    @staticmethod
    def decode(raw: bytes) -> "CompressedTensor":
        scale, ndim = struct.unpack_from("<dB", raw, 0)
        off = 9
        shape = struct.unpack_from(f"<{ndim}q", raw, off)
        off += 8 * ndim
        q = np.frombuffer(raw, np.int8, offset=off)
        return CompressedTensor(q.copy(), scale, tuple(shape))


register_migratable(
    CompressedTensor,
    encode=lambda t: t.encode(),
    decode=CompressedTensor.decode,
    type_name="ham:compressed_tensor",
)
