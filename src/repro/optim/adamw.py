"""AdamW with global-norm clipping, pytree-native (no optax dependency).

``init``/``update`` are pure functions; the optimizer state mirrors the
param tree (so the dry-run shards it with the same PartitionSpecs as the
parameters — ZeRO falls out of FSDP rules for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # distributed-optimisation knobs:
    #   reduce_dtype: cast gradients before the DP all-reduce (bf16 halves
    #   collective bytes); state_dtype: Adam moment storage (bf16 halves
    #   optimizer HBM — required to fit the 340B/405B configs on v5e)
    reduce_dtype: str | None = None
    state_dtype: str = "float32"


def init(params, *, state_dtype: str = "float32") -> dict:
    sd = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, sd)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
            for l in jax.tree_util.tree_leaves(tree))
    )


def update(cfg: AdamWConfig, params, opt_state, grads):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, step)

    sd = jnp.dtype(cfg.state_dtype)

    def leaf(p, m, v, g):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * upd).astype(p.dtype),
                m.astype(sd), v.astype(sd))

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_m = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_v = jax.tree_util.tree_leaves(opt_state["nu"])
    flat_g = jax.tree_util.tree_leaves(grads)
    out = [leaf(p, m, v, g) for p, m, v, g in zip(flat_p, flat_m, flat_v, flat_g)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"mu": new_m, "nu": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
